#!/usr/bin/env bash
# Tier-1 gate + serving smoke. Run from anywhere; no PYTHONPATH needed
# (pyproject.toml sets pythonpath=src for pytest; the serve smoke exports
# it for the module launch).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: full test suite =="
python -m pytest -x -q

echo "== serving smoke: continuous batching + bitmap-compressed head =="
PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
    --sparsity 0.5 --slots 2 --requests 6 --max-len 64

echo "== bench smoke: whole-stack bitmap streaming (attn/MLP + MoE + jamba hybrid) -> BENCH_serve.json =="
PYTHONPATH=src python benchmarks/bitmap_streaming.py --smoke \
    --archs olmo-1b granite-moe-3b-a800m jamba-v0.1-52b \
    --sparsities 0.0 0.75 --slots 2 --requests 8 --max-len 32 --repeats 2 \
    --out BENCH_serve.json

echo "== spmd smoke: sharded serving on 8 fake devices (mp=4 vs mp=1 bit-identical, per-device ledger gate) =="
PYTHONPATH=src python scripts/spmd_smoke.py --arch olmo-1b --mp 4

echo "== bench smoke: sharded serving cell -> BENCH_serve.json (model_parallel) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
    python benchmarks/bitmap_streaming.py --smoke \
    --archs olmo-1b granite-moe-3b-a800m --sparsities 0.75 \
    --requests 6 --max-len 32 --repeats 1 --model-parallel 4 \
    --out BENCH_serve.json

echo "== manifest coverage report (MoE expert stacks + SSM mixers packed) =="
PYTHONPATH=src python scripts/manifest_report.py \
    --archs granite-moe-3b-a800m jamba-v0.1-52b

echo "== bench smoke: paged KV cache -> BENCH_serve.json (paging) =="
PYTHONPATH=src python benchmarks/paged_serving.py --smoke \
    --page-lens 8 --slots 2 --requests 8 --max-len 128 --repeats 2 \
    --out BENCH_serve.json

echo "== bench smoke: chunked prefill -> BENCH_serve.json (prefill) =="
PYTHONPATH=src python benchmarks/prefill.py --smoke \
    --chunks 8 --slots 2 --requests 6 --max-len 64 --repeats 2 \
    --out BENCH_serve.json

echo "== bench smoke: shared-prefix COW reuse + preemption -> BENCH_serve.json (prefix_reuse) =="
PYTHONPATH=src python benchmarks/prefix_reuse.py --smoke \
    --requests 10 --max-len 64 --repeats 2 \
    --out BENCH_serve.json

echo "== chaos smoke: seeded fault injection under audit (two archs) =="
PYTHONPATH=src python scripts/chaos_smoke.py --archs olmo-1b gemma3-4b

echo "== bench smoke: overload goodput / shed rate -> BENCH_serve.json (overload) =="
PYTHONPATH=src python benchmarks/overload.py --smoke \
    --requests 16 --max-len 48 --out BENCH_serve.json

echo "== telemetry smoke: trace/events/metrics artifacts + on==off token identity =="
PYTHONPATH=src python scripts/telemetry_smoke.py --arch olmo-1b
PYTHONPATH=src python scripts/trace_report.py \
    /tmp/repro_telemetry_smoke/serve.trace.json --validate

echo "== traffic observatory: artifact + budget gate + roofline merge (two archs) =="
mkdir -p /tmp/repro_traffic_smoke
for arch in olmo-1b granite-moe-3b-a800m; do
    PYTHONPATH=src python -m repro.launch.serve --arch "$arch" --smoke \
        --sparsity 0.5 --slots 2 --requests 6 --max-len 64 \
        --traffic-out "/tmp/repro_traffic_smoke/$arch.traffic.json" \
        --trace-out "/tmp/repro_traffic_smoke/$arch.trace.json"
    PYTHONPATH=src python scripts/traffic_report.py \
        "/tmp/repro_traffic_smoke/$arch.traffic.json" \
        --budget scripts/traffic_budget.json
    PYTHONPATH=src python scripts/trace_report.py \
        "/tmp/repro_traffic_smoke/$arch.trace.json" --validate --traffic
done
PYTHONPATH=src python benchmarks/roofline.py \
    --serve-artifacts /tmp/repro_traffic_smoke/*.traffic.json \
    --out BENCH_serve.json

echo "CI OK"
