"""Render a serve-engine Chrome trace as terminal tables.

Reads a ``--trace-out`` artifact (see DESIGN_SERVING.md §Observability)
and prints:

* a **phase-time breakdown** — per step-phase: span count, total /
  mean / p95 milliseconds, and the share of the summed step wall each
  phase accounts for (the software analogue of a per-component access
  counter readout — where does a serving step's time actually go);
* a **per-request TTFT waterfall** — one row per request, QUEUED /
  PREFILL / DECODE segments drawn to a common time axis, with the
  request's terminal state, token count, and measured TTFT;
* with ``--traffic``, a **per-phase HBM counter view** — the
  ``hbm.decode`` / ``hbm.prefill`` counter tracks the traffic ledger
  emitted into the trace: per series, sample count and total / mean /
  max bytes per step.

Run:
  PYTHONPATH=src python scripts/trace_report.py serve.trace.json
  PYTHONPATH=src python scripts/trace_report.py serve.trace.json --traffic
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.serve.telemetry import PHASES, load_trace, validate_trace


def _pctl(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def phase_breakdown(events: List[Dict]) -> List[Dict]:
    """Per-phase aggregate rows (milliseconds), sorted by total time."""
    steps = [e for e in events
             if e.get("ph") == "X" and e.get("cat") == "step"]
    step_wall_ms = sum(e["dur"] for e in steps) / 1e3
    rows = []
    for name in PHASES:
        durs = sorted(e["dur"] / 1e3 for e in events
                      if e.get("ph") == "X" and e.get("cat") == "phase"
                      and e["name"] == name)
        if not durs:
            continue
        total = sum(durs)
        rows.append({
            "phase": name,
            "count": len(durs),
            "total_ms": total,
            "mean_ms": total / len(durs),
            "p95_ms": _pctl(durs, 0.95),
            "share": total / step_wall_ms if step_wall_ms else 0.0,
        })
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def print_phase_table(events: List[Dict]) -> None:
    rows = phase_breakdown(events)
    steps = [e for e in events
             if e.get("ph") == "X" and e.get("cat") == "step"]
    wall_ms = sum(e["dur"] for e in steps) / 1e3
    print(f"phase breakdown over {len(steps)} steps "
          f"({wall_ms:.1f}ms stepped wall):")
    hdr = (f"  {'phase':<14} {'count':>5} {'total ms':>9} "
           f"{'mean ms':>8} {'p95 ms':>8} {'share':>6}")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    covered = 0.0
    for r in rows:
        covered += r["share"]
        print(f"  {r['phase']:<14} {r['count']:>5} {r['total_ms']:>9.2f} "
              f"{r['mean_ms']:>8.3f} {r['p95_ms']:>8.3f} "
              f"{r['share']:>6.1%}")
    print(f"  {'(covered)':<14} {'':>5} {'':>9} {'':>8} {'':>8} "
          f"{covered:>6.1%}")


def traffic_breakdown(events: List[Dict]) -> List[Dict]:
    """Aggregate the ph="C" traffic counter tracks: one row per
    (track, series) with sample count and total / mean / max bytes."""
    samples: Dict[tuple, List[float]] = {}
    for e in events:
        if e.get("ph") != "C" or e.get("cat") != "traffic":
            continue
        for series, val in (e.get("args") or {}).items():
            samples.setdefault((e["name"], series), []).append(float(val))
    rows = []
    for (track, series), vals in sorted(samples.items()):
        rows.append({
            "track": track,
            "series": series,
            "count": len(vals),
            "total": sum(vals),
            "mean": sum(vals) / len(vals),
            "max": max(vals),
        })
    return rows


def print_traffic_table(events: List[Dict]) -> None:
    rows = traffic_breakdown(events)
    if not rows:
        print("no traffic counter events in trace "
              "(run serve with --trace-out on an engine build that "
              "emits hbm.* counter tracks)")
        return
    print("HBM traffic counters (bytes per step sample):")
    hdr = (f"  {'track':<12} {'series':<16} {'count':>5} "
           f"{'total MB':>9} {'mean kB':>8} {'max kB':>8}")
    print(hdr)
    print("  " + "-" * (len(hdr) - 2))
    for r in rows:
        print(f"  {r['track']:<12} {r['series']:<16} {r['count']:>5} "
              f"{r['total'] / 1e6:>9.3f} {r['mean'] / 1e3:>8.1f} "
              f"{r['max'] / 1e3:>8.1f}")


_SEG_CHARS = {"QUEUED": "░", "PREFILL": "▒", "DECODE": "█"}


def request_waterfall(events: List[Dict]) -> List[Dict]:
    """One row per request: lifecycle segments in trace-relative
    seconds plus the span args (state / tokens / measured TTFT)."""
    per_rid: Dict[int, Dict] = {}
    for e in events:
        if e.get("ph") != "X" or e.get("cat") != "request":
            continue
        row = per_rid.setdefault(
            e["tid"], {"rid": e["tid"], "segments": {}, "args": {}})
        row["segments"][e["name"]] = (e["ts"] / 1e6,
                                      (e["ts"] + e["dur"]) / 1e6)
        row["args"].update(e.get("args") or {})
    rows = sorted(per_rid.values(),
                  key=lambda r: min(t0 for t0, _ in
                                    r["segments"].values()))
    return rows


def print_waterfall(events: List[Dict], width: int = 48) -> None:
    rows = request_waterfall(events)
    if not rows:
        print("no request spans in trace")
        return
    t_lo = min(t0 for r in rows for t0, _ in r["segments"].values())
    t_hi = max(t1 for r in rows for _, t1 in r["segments"].values())
    span = max(t_hi - t_lo, 1e-9)

    def col(t: float) -> int:
        return min(width - 1, max(0, int((t - t_lo) / span * width)))

    print(f"request waterfall ({len(rows)} requests, "
          f"{span * 1e3:.1f}ms window; "
          f"{'/'.join(f'{c}={n}' for n, c in _SEG_CHARS.items())}):")
    print(f"  {'rid':>4} {'state':<9} {'tok':>4} {'ttft ms':>8}  timeline")
    for r in rows:
        lane = [" "] * width
        for name in ("QUEUED", "PREFILL", "DECODE"):
            seg = r["segments"].get(name)
            if seg is None:
                continue
            c0, c1 = col(seg[0]), col(seg[1])
            for i in range(c0, max(c0 + 1, c1)):
                lane[i] = _SEG_CHARS[name]
        a = r["args"]
        ttft = a.get("first_token_ms")
        print(f"  {r['rid']:>4} {a.get('state', '?'):<9} "
              f"{a.get('tokens', 0):>4} "
              f"{ttft if ttft is not None else '-':>8}  "
              f"|{''.join(lane)}|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace JSON from --trace-out")
    ap.add_argument("--width", type=int, default=48,
                    help="waterfall timeline width in characters")
    ap.add_argument("--validate", action="store_true",
                    help="run structural validation (nesting, overlap, "
                         "lifecycle order) before rendering")
    ap.add_argument("--traffic", action="store_true",
                    help="render the per-phase HBM byte counter tracks "
                         "(hbm.decode / hbm.prefill) instead of the "
                         "time tables")
    args = ap.parse_args()
    events = load_trace(args.trace)
    if args.validate:
        stats = validate_trace(events)
        cov = stats["agg_coverage"]
        print(f"trace OK: {stats['steps']} steps, "
              f"{stats['phase_spans']} phase spans, "
              f"{stats['requests']} requests"
              + (f", phase/wall coverage {cov:.1%}"
                 if cov is not None else ""))
    if args.traffic:
        print_traffic_table(events)
        return
    print_phase_table(events)
    print()
    print_waterfall(events, width=args.width)


if __name__ == "__main__":
    main()
