#!/usr/bin/env python
"""Render a serving traffic artifact (--traffic-out) and gate it.

Three jobs, composable in one invocation:

* table — per-role HBM attribution (sparse vs dense bytes per step,
  share of the stream), per-phase byte totals, KV accounting, energy
  projection;
* cross-check — the modeled-vs-compiled delta per phase, exiting
  nonzero when a phase's ratio left its tolerance band;
* budget gate — compare the run's modeled + compiled bytes against the
  checked-in per-arch budget (``scripts/traffic_budget.json``), exiting
  nonzero when any gated figure regressed beyond the budget's
  tolerance.  ``--update-budget`` reseeds the arch's budget entry from
  the current artifact instead of gating (run it once after an
  intentional traffic change and commit the file).

Usage:
  python scripts/traffic_report.py /tmp/traffic.json
  python scripts/traffic_report.py /tmp/traffic.json \
      --budget scripts/traffic_budget.json
  python scripts/traffic_report.py /tmp/traffic.json \
      --budget scripts/traffic_budget.json --update-budget
"""
from __future__ import annotations

import argparse
import json
import sys

#: figures the budget file pins, as (label, extractor) — modeled bytes
#: catch regressions in the analytical model / packing, compiled bytes
#: catch regressions in what XLA actually emits
GATED = {
    "weight_sparse_bytes_per_step":
        lambda tr: tr["weight"]["sparse_bytes_per_step"],
    "weight_dense_bytes_per_step":
        lambda tr: tr["weight"]["dense_bytes_per_step"],
    "compiled_decode_bytes":
        lambda tr: (tr["crosscheck"] or {}).get("decode", {}).get(
            "compiled_bytes"),
}


def _mb(b: float) -> str:
    return f"{b / 1e6:8.3f}"


def print_tables(doc: dict) -> None:
    tr = doc["traffic"]
    print(f"arch {doc['arch']}  sparsity {doc.get('sparsity', 0):.2f}  "
          f"slots {doc.get('num_slots', '?')}")
    print(f"\n{'role':<14s} {'tensors':>7s} {'sparse MB':>10s} "
          f"{'dense MB':>10s} {'ratio':>6s} {'share':>6s}")
    roles = tr["per_role"]
    tot_s = sum(r["sparse_bytes"] for r in roles.values()) or 1
    for role, r in sorted(roles.items(),
                          key=lambda kv: -kv[1]["sparse_bytes"]):
        ratio = (r["dense_bytes"] / r["sparse_bytes"]
                 if r["sparse_bytes"] else 1.0)
        print(f"{role:<14s} {r['tensors']:>7d} "
              f"{_mb(r['sparse_bytes']):>10s} "
              f"{_mb(r['dense_bytes']):>10s} {ratio:>6.2f} "
              f"{r['sparse_bytes'] / tot_s:>6.1%}")
    w = tr["weight"]
    print(f"{'total':<14s} {sum(r['tensors'] for r in roles.values()):>7d} "
          f"{_mb(w['sparse_bytes_per_step']):>10s} "
          f"{_mb(w['dense_bytes_per_step']):>10s} "
          f"{w['reduction']:>6.2f}")

    print(f"\n{'phase':<10s} {'steps':>6s} {'weight MB':>10s} "
          f"{'kv read MB':>11s} {'kv write MB':>12s}")
    for ph, row in tr["phases"].items():
        steps = row.get("steps", row.get("calls", 0))
        print(f"{ph:<10s} {steps:>6d} {_mb(row['weight_bytes']):>10s} "
              f"{_mb(row['kv_read_bytes']):>11s} "
              f"{_mb(row['kv_write_bytes']):>12s}")
    kv = tr["kv"]
    print(f"\nKV: {kv['line_bytes_per_token']}B/token-line, "
          f"{kv['read_bytes'] / 1e6:.3f}MB read / "
          f"{kv['write_bytes'] / 1e6:.3f}MB written"
          + (f", {kv['prefix_saved_bytes'] / 1e6:.3f}MB saved by prefix "
             f"reuse" if kv["prefix_saved_bytes"] else ""))
    en = tr["energy"]
    print(f"energy: {en['pj_per_token'] / 1e6:.3f}uJ/token sparse vs "
          f"{en['pj_per_token_dense'] / 1e6:.3f}uJ/token dense | "
          f"{en['tops_per_watt']:.2f} vs {en['tops_per_watt_dense']:.2f} "
          f"TOPS/W ({en['macs_per_token']} MACs/token)")
    for ph, rl in tr["roofline"].items():
        print(f"roofline[{ph}]: {rl['bottleneck']}-bound "
              f"(compute {rl['compute_s'] * 1e6:.2f}us / memory "
              f"{rl['memory_s'] * 1e6:.2f}us)")


def check_crosscheck(doc: dict) -> bool:
    cx = doc["traffic"]["crosscheck"]
    if cx is None:
        print("\ncross-check: not run (artifact written without it?)")
        return True
    ok = True
    print(f"\ncross-check (dispatch: {cx['dispatch']}):")
    for ph in ("decode", "prefill"):
        if ph not in cx:
            continue
        e = cx[ph]
        lo, hi = e["tolerance"]
        good = e["within_band"]
        ok &= good
        print(f"  {ph}: modeled {e['modeled']['total_bytes'] / 1e6:.3f}MB "
              f"vs compiled {e['compiled_bytes'] / 1e6:.3f}MB — ratio "
              f"{e['ratio']:.2f} in [{lo:g}, {hi:g}] "
              f"{'ok' if good else 'VIOLATED'}")
    return ok


def gate(doc: dict, budget_path: str, update: bool) -> bool:
    tr = doc["traffic"]
    try:
        with open(budget_path) as f:
            budgets = json.load(f)
    except FileNotFoundError:
        budgets = {}
    arch = doc["arch"]
    current = {k: fn(tr) for k, fn in GATED.items()}
    current = {k: v for k, v in current.items() if v is not None}
    if update:
        entry = budgets.setdefault(arch, {"tolerance": 0.15})
        entry.update(current)
        with open(budget_path, "w") as f:
            json.dump(budgets, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"\nbudget updated: {arch} -> {budget_path}")
        return True
    entry = budgets.get(arch)
    if entry is None:
        print(f"\nno budget entry for {arch} in {budget_path} — run with "
              f"--update-budget to seed one", file=sys.stderr)
        return False
    tol = entry.get("tolerance", 0.15)
    ok = True
    print(f"\nbudget gate ({budget_path}, tolerance {tol:.0%}):")
    for key, val in current.items():
        ref = entry.get(key)
        if ref is None:
            continue
        ceil = ref * (1.0 + tol)
        good = val <= ceil
        ok &= good
        print(f"  {key}: {val / 1e6:.3f}MB vs budget {ref / 1e6:.3f}MB "
              f"(ceiling {ceil / 1e6:.3f}MB) "
              f"{'ok' if good else 'REGRESSED'}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help="traffic JSON from --traffic-out")
    ap.add_argument("--budget", default=None,
                    help="per-arch budget file to gate against "
                         "(scripts/traffic_budget.json)")
    ap.add_argument("--update-budget", action="store_true",
                    help="reseed this arch's budget entry from the "
                         "artifact instead of gating")
    args = ap.parse_args()
    with open(args.artifact) as f:
        doc = json.load(f)
    if doc.get("schema") != "repro.serve.traffic/v1":
        print(f"unrecognized artifact schema: {doc.get('schema')!r}",
              file=sys.stderr)
        return 2
    print_tables(doc)
    ok = check_crosscheck(doc)
    if args.budget:
        ok &= gate(doc, args.budget, args.update_budget)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
