"""Chaos smoke: the full seeded fault schedule under audit, per arch.

Runs ``FaultPlan.chaos(seed)`` against the full serving stack (paged KV
+ prefix reuse + preemption + chunked prefill) with ``audit=True`` and
asserts the hard guarantees the fault-injection harness exists to
enforce:

* every submitted request terminates in a typed terminal state;
* zero invariant-audit violations across every step;
* zero page leaks after drain (refcount conservation holds);
* the served tokens are *bit-identical* to a never-faulted run —
  corruption quarantines to dense (packing is lossless) and preempted
  requests replay deterministically.

Exit status is the CI contract: non-zero on any violated guarantee.

  PYTHONPATH=src python scripts/chaos_smoke.py --archs olmo-1b gemma3-4b
"""
from __future__ import annotations

import argparse
import warnings

from repro.configs import get_smoke_config
from repro.serve import FaultPlan, RequestState, ServeEngine, poisson_trace


def _run(cfg, seed: int, faults=None, audit: bool = False):
    eng = ServeEngine(cfg, num_slots=2, max_len=64, sparsity=0.5,
                      seed=seed, paged=True, page_len=8,
                      prefix_reuse=True, preempt=True, prefill_chunk=4,
                      audit=audit, faults=faults)
    trace = poisson_trace(8, rate=0.5, seed=seed,
                          vocab_size=eng.cfg.vocab_size,
                          prompt_len=(1, 6), max_new=(4, 10))
    with eng.mesh:
        reqs = [eng.submit(**spec) for spec in trace]
        rep = eng.run()
    return eng, rep, {r.rid: list(r.tokens) for r in reqs}


def chaos_smoke(arch: str, seed: int) -> None:
    cfg = get_smoke_config(arch)
    _, _, clean = _run(cfg, seed)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # quarantine warnings expected
        eng, rep, toks = _run(cfg, seed, audit=True,
                              faults=FaultPlan.chaos(seed=seed))
    fs = rep["lifecycle"]["faults"]
    assert fs["fired"] >= 3, f"chaos plan barely fired: {fs['log']}"
    for r in eng.requests:
        assert r.state in (RequestState.DONE,), \
            f"rid {r.rid} ended {r.state.name}"
    assert toks == clean, "faulted tokens diverged from the clean run"
    eng.kv.flush_prefix()
    eng.kv.audit()
    for pool in eng.kv.pools.values():
        assert not pool.ref and not pool.held, "page leak"
    au = rep["lifecycle"]["audit"]
    print(f"[{arch}] {fs['fired']}/{fs['planned']} faults fired "
          f"(seed {seed}), {au['steps_checked']} steps audited, "
          f"{len(rep['lifecycle']['quarantined'])} tensors quarantined, "
          f"tokens bit-identical, zero leaks")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+", default=["olmo-1b", "gemma3-4b"])
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()
    for arch in args.archs:
        chaos_smoke(arch, args.seed)
    print("chaos smoke OK")


if __name__ == "__main__":
    main()
