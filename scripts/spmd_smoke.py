"""SPMD serving smoke: sharded packed serving on 8 fake devices.

Forces an 8-device host platform (``--xla_force_host_platform_device_
count=8`` — set before jax imports, so run this script directly), then
serves the same Poisson-ish trace twice on the elastic (data, model)
mesh — once replicated (``mp=1``, KV data-sharded 8 ways) and once
tensor-parallel (``mp=4``, weights column/row-sharded, KV data-sharded
2 ways) — and gates on the hard guarantees:

* tokens are bit-identical between the two topologies (greedy and
  temperature-sampled requests alike);
* zero fallbacks blamed on ``model_parallel`` (those paths are gone)
  and zero per-tensor shard fallbacks on the smoke shapes;
* the per-device weight ledger: traffic's device columns equal the
  engine's by construction, and summed over sharded manifest entries
  the per-device packed bytes are the totals floor-divided by mp.

Exit status is the CI contract: non-zero on any violated guarantee.

  PYTHONPATH=src python scripts/spmd_smoke.py --arch olmo-1b --mp 4
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import warnings


def _run(arch: str, mp: int, sparsity: float, requests: int,
         max_len: int):
    from repro.configs import get_smoke_config
    from repro.serve import ServeEngine

    cfg = get_smoke_config(arch)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # e.g. granite's dense head
        eng = ServeEngine(cfg, num_slots=8, max_len=max_len,
                          sparsity=sparsity, model_parallel=mp, seed=0,
                          paged=True, page_len=8, prefill_chunk=8,
                          prefix_reuse=True, preempt=True)
    prompts = [[1 + (i * 7 + j) % 250 for j in range(5 + i % 4)]
               for i in range(requests)]
    reqs = [eng.submit(p, max_new_tokens=6, arrival=float(i // 2),
                       temperature=(0.8 if i % 2 else 0.0), seed=100 + i,
                       top_k=(8 if i % 2 else None))
            for i, p in enumerate(prompts)]
    rep = eng.run()
    eng.kv.audit()
    return eng, rep, {r.rid: list(r.tokens) for r in reqs}


def _gate_ledger(eng, rep, mp: int) -> None:
    ws = rep["weight_stream"]
    tw = rep["traffic"]["weight"]
    assert ws["shards"] == mp, (ws["shards"], mp)
    assert ws["shard_fallbacks"] == {}, ws["shard_fallbacks"]
    for key, reason in rep["fallbacks"].items():
        assert "model_parallel" not in reason, (key, reason)
    # ledger == engine on the device columns (single-sourced accounting)
    for col in ("sparse_bytes_per_step", "device_sparse_bytes_per_step",
                "device_dense_bytes_per_step"):
        assert tw[col] == ws[col], (col, tw[col], ws[col])
    # per-device packed bytes == totals / mp, floor-div per tensor
    dev = tot = n = 0
    for e in eng.packed.manifest:
        if e.shard is not None:
            n += 1
            tot += int(e.sparse_bytes)
            dev += int(e.sparse_bytes) // e.shard[1]
    if mp > 1:
        assert n > 0, "nothing sharded at mp>1"
        assert dev * mp <= tot < dev * mp + mp * n, (dev, tot, n)
    else:
        assert dev == tot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--mp", type=int, default=4)
    ap.add_argument("--sparsity", type=float, default=0.75)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=48)
    args = ap.parse_args()

    import jax
    ndev = jax.device_count()
    assert ndev % args.mp == 0, (ndev, args.mp)

    eng1, rep1, base = _run(args.arch, 1, args.sparsity, args.requests,
                            args.max_len)
    engN, repN, toks = _run(args.arch, args.mp, args.sparsity,
                            args.requests, args.max_len)
    assert engN._spmd and eng1._spmd
    assert dict(engN.mesh.shape) == {"data": ndev // args.mp,
                                     "model": args.mp}
    assert toks == base, "mp=%d tokens diverged from mp=1" % args.mp
    _gate_ledger(eng1, rep1, 1)
    _gate_ledger(engN, repN, args.mp)

    ws = repN["weight_stream"]
    print(f"[{args.arch}] mesh {dict(engN.mesh.shape)}: "
          f"{args.requests} requests bit-identical mp=1 vs mp={args.mp}, "
          f"kv shards {eng1.kv.shards}->{engN.kv.shards}, per-device "
          f"sparse {ws['device_sparse_bytes_per_step']}B of "
          f"{ws['sparse_bytes_per_step']}B/step, zero shard fallbacks")
    print("spmd smoke OK")


if __name__ == "__main__":
    main()
