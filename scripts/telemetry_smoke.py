"""CI telemetry smoke: telemetry-on == telemetry-off, artifacts valid.

Runs the same seeded Poisson trace through the engine twice — once with
all three telemetry outputs on, once fully off — and asserts the
observability contract (DESIGN_SERVING.md §Observability):

* served tokens and terminal states are **bit-identical** on vs off
  (telemetry never perturbs scheduling or numerics);
* the Chrome trace parses, every phase span nests inside a step span
  without overlap (``validate_trace``), and summed phase time covers
  >= ``--min-coverage`` of the summed measured step wall — the 5 %
  criterion: the phase taxonomy accounts for where step time goes;
* the JSONL event log parses, every record matches the event schema,
  and timestamps are monotonic (``validate_events``);
* the metrics snapshot is valid JSON carrying the registry schema tag.

Run (CI does):
  PYTHONPATH=src python scripts/telemetry_smoke.py --arch olmo-1b
"""
from __future__ import annotations

import argparse
import json
import os

from repro.serve import (ServeEngine, poisson_trace, validate_events,
                         validate_trace)


def run_once(arch: str, out_dir: str | None, *, requests: int,
             max_len: int, seed: int) -> tuple:
    kw = {}
    if out_dir is not None:
        kw = {"trace_out": os.path.join(out_dir, "serve.trace.json"),
              "events_out": os.path.join(out_dir, "serve.events.jsonl"),
              "metrics_out": os.path.join(out_dir, "serve.metrics.json")}
    eng = ServeEngine.from_arch(arch, smoke=True, num_slots=2,
                                max_len=max_len, sparsity=0.5,
                                paged=True, page_len=8, prefill_chunk=8,
                                prefix_reuse=True, preempt=True,
                                audit=True, **kw)
    trace = poisson_trace(requests, rate=0.5, seed=seed,
                          vocab_size=eng.cfg.vocab_size,
                          prompt_len=(1, 6), max_new=(2, 6))
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)
        eng.run()
    eng.close()
    served = [(r.rid, r.state.name, list(r.tokens))
              for r in eng.requests]
    return eng, served


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-coverage", type=float, default=0.95,
                    help="required duration-weighted phase/wall "
                         "coverage floor across the trace")
    ap.add_argument("--out-dir", default="/tmp/repro_telemetry_smoke")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    eng_on, served_on = run_once(args.arch, args.out_dir,
                                 requests=args.requests,
                                 max_len=args.max_len, seed=args.seed)
    eng_off, served_off = run_once(args.arch, None,
                                   requests=args.requests,
                                   max_len=args.max_len, seed=args.seed)
    assert eng_off.telemetry is None and eng_off.spans is None
    assert served_on == served_off, (
        "telemetry-on run diverged from telemetry-off:\n"
        f"on:  {served_on}\noff: {served_off}")
    print(f"tokens bit-identical on vs off "
          f"({sum(len(t) for _, _, t in served_on)} tokens over "
          f"{len(served_on)} requests)")

    trace_path = os.path.join(args.out_dir, "serve.trace.json")
    stats = validate_trace(trace_path)
    cov = stats["agg_coverage"]
    assert cov is not None and cov >= args.min_coverage, (
        f"phase coverage {cov} below the {args.min_coverage:.0%} floor "
        f"— the phase taxonomy is leaking step wall time")
    print(f"trace OK: {stats['steps']} steps / {stats['phase_spans']} "
          f"phase spans / {stats['requests']} request rows, phase/wall "
          f"coverage {cov:.1%} (min step {stats['min_coverage']:.1%})")

    events_path = os.path.join(args.out_dir, "serve.events.jsonl")
    n = validate_events(events_path)
    assert n > 0, "event log is empty"
    print(f"events OK: {n} records, schema + monotonicity hold")

    metrics_path = os.path.join(args.out_dir, "serve.metrics.json")
    with open(metrics_path) as f:
        snap = json.load(f)
    assert snap.get("schema") == "repro.serve.metrics/v1", snap.get(
        "schema")
    assert "step.wall_s" in snap["metrics"], "step histograms missing"
    print(f"metrics OK: {len(snap['metrics'])} metrics in snapshot")
    print(f"telemetry smoke OK (artifacts in {args.out_dir})")


if __name__ == "__main__":
    main()
