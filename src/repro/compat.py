"""Version compatibility helpers for jax APIs (single home — see also
kernels/pallas_compat.py for the Pallas-specific aliases).

``jax.shard_map`` is top-level only in newer jax; 0.4.x keeps it under
``jax.experimental.shard_map`` and names the replication-check kwarg
``check_rep`` instead of ``check_vma``.
"""
from __future__ import annotations

import inspect

import jax

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(shard_map).parameters
             else "check_rep")


def shard_map_nocheck(f, mesh, in_specs, out_specs):
    """shard_map with the replication check disabled, any jax version."""
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **{_CHECK_KW: False})
