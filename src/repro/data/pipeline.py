"""Deterministic sharded synthetic-token pipeline with prefetch.

Design points that matter at cluster scale:

* **step-indexed determinism** — batch ``i`` is a pure function of
  (seed, step, host), so a restarted/elastic job resumes mid-stream with no
  data replay or skip bookkeeping (straggler/restart mitigation);
* **host sharding** — each host materialises only its slice of the global
  batch (``process_index``-strided rows);
* **prefetch** — a background thread keeps ``depth`` batches ready so host
  data generation overlaps device compute.

The generator is a marked-Zipf synthetic LM stream (repeatable structure so
loss actually drops during the examples' training runs).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    structure_period: int = 16   # injects learnable periodic structure


def _batch_rng(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def synth_batch(model_cfg: ModelConfig, cfg: DataConfig, step: int,
                host: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Materialise this host's slice of global batch ``step``."""
    assert cfg.global_batch % num_hosts == 0
    b = cfg.global_batch // num_hosts
    s = cfg.seq_len
    rng = _batch_rng(cfg, step, host)
    v = model_cfg.vocab_size
    # zipf-distributed tokens; odd positions copy their predecessor, giving
    # the model learnable structure (loss verifiably drops in the examples)
    base = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64) % v
    odd = base[:, 1::2]
    base[:, 1::2] = base[:, 0::2][:, :odd.shape[1]]
    tokens = base.astype(np.int32)

    batch: Dict[str, np.ndarray] = {}
    if model_cfg.frontend == "frames":
        batch["embeds"] = rng.standard_normal(
            (b, s, model_cfg.d_model)).astype(np.float32)
        batch["targets"] = tokens
    elif model_cfg.frontend == "patches":
        fl = model_cfg.frontend_len
        batch["embeds"] = rng.standard_normal(
            (b, fl, model_cfg.d_model)).astype(np.float32)
        batch["tokens"] = tokens[:, :s - fl]
        tg = np.concatenate(
            [np.full((b, fl), -1, np.int32), tokens[:, :s - fl]], axis=1)
        batch["targets"] = tg
    else:
        batch["tokens"] = tokens
        # next-token targets with the final position masked
        tg = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        batch["targets"] = tg
    return batch


class Prefetcher:
    """Background-thread prefetch of ``synth_batch`` outputs."""

    def __init__(self, model_cfg: ModelConfig, cfg: DataConfig,
                 start_step: int = 0, depth: int = 2,
                 host: int = 0, num_hosts: int = 1):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = synth_batch(model_cfg, cfg, step, host, num_hosts)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.25)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
