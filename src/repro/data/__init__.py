"""Deterministic sharded synthetic data pipeline."""
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch

__all__ = ["DataConfig", "Prefetcher", "synth_batch"]
