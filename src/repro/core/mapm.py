"""MAPM (Memory Access per MAC) analytics — paper §I and §III-A.

MAPM = bytes of on-chip SRAM buffer traffic per executed MAC (byte/MAC) with
8-bit operands and 1-byte output write-back, matching the paper's dense 4×4
example: (16 inputs + 16 weights + 16 outputs) / 64 MACs = 0.75 B/MAC.

Baseline dataflow models (the designs the paper compares against):

* ``dense_output_stationary`` — classic dense DLA (Eyeriss/VWA style):
  every input/weight read once per tile, outputs written once.
* ``sparten``   — dot-product dataflow, reuses only outputs: both operands of
  every MAC are fetched from SRAM (2 B/MAC) + output write-back + a matching
  overhead for re-fetch on failed prefix-sum matches.  The paper measured
  2.09 B/MAC for SparTen; our first-principles model gives ≈2.0 and we keep
  the paper's measured value as the comparison reference.
* ``scnn``      — Cartesian-product dataflow, reuses only inputs: operands are
  amortised but every MAC's partial sum is written to and read back from the
  psum SRAM (2 B/MAC).  Paper measured 2.03 B/MAC.
* ``ours``      — measured by the SIDR cycle simulator (``repro.core.sidr``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataflowCounts:
    macs: int
    sram_bytes: float

    @property
    def mapm(self) -> float:
        return self.sram_bytes / max(self.macs, 1)


def _tile_counts(m: int, n: int, k: int, tile: int = 16):
    tiles_m = -(-m // tile)
    tiles_n = -(-n // tile)
    return tiles_m, tiles_n


def dense_output_stationary(m: int, n: int, k: int, tile: int = 16
                            ) -> DataflowCounts:
    """Dense DLA with full broadcast reuse on a tile×tile array.

    Per (tile_m, tile_n) output tile: read tile·K inputs + tile·K weights,
    write tile² outputs. MACs = m·n·k (zeros are not skipped).
    """
    tm, tn = _tile_counts(m, n, k, tile)
    reads = tm * tn * (tile * k + tile * k)
    writes = tm * tn * tile * tile
    return DataflowCounts(macs=m * n * k, sram_bytes=reads + writes)


def sparten(nnz_macs: int, num_outputs: int,
            match_refetch: float = 0.0) -> DataflowCounts:
    """SparTen-style dot-product dataflow (output reuse only)."""
    bytes_ = (2.0 + 2.0 * match_refetch) * nnz_macs + num_outputs
    return DataflowCounts(macs=nnz_macs, sram_bytes=bytes_)


SPARTEN_PAPER_MAPM = 2.09  # measured value reported in the paper
SCNN_PAPER_MAPM = 2.03


def scnn(nnz_macs: int, nnz_inputs: int, nnz_weights: int) -> DataflowCounts:
    """SCNN-style Cartesian-product dataflow (input reuse only).

    Inputs/weights are each fetched once; every MAC's partial sum is written
    to and read back from the psum buffer (scatter-accumulate).
    """
    bytes_ = nnz_inputs + nnz_weights + 2.0 * nnz_macs
    return DataflowCounts(macs=nnz_macs, sram_bytes=bytes_)


def sparse_macs(x: np.ndarray, w: np.ndarray) -> int:
    """Number of non-zero MACs of X (M,K) @ W(N,K)^T."""
    bx = (np.asarray(x) != 0).astype(np.int64)
    bw = (np.asarray(w) != 0).astype(np.int64)
    return int((bx @ bw.T).sum())


def reduction_vs_sparten(our_mapm: float,
                         sparten_mapm: float = SPARTEN_PAPER_MAPM) -> float:
    """Fractional SRAM-access reduction (paper headline: 86 % vs SparTen)."""
    return 1.0 - our_mapm / sparten_mapm
