"""Shared Index Data Reuse (SIDR) — cycle-level simulator of Algorithm 1.

Simulates the 16×16 output-stationary PE array with 8-entry shared registers
per row (inputs) and per column (weights).  Fully vectorised over a leading
batch of tiles, so whole GEMMs are simulated as one numpy program.

Faithful semantics (paper Algorithm 1):
  * per-PE EIM FIFOs hold (EffI, EffW) streams (from ``repro.core.eim``);
  * a PE pops a new pair only if it was not IDLE in the previous iteration;
  * SharedI_m = min over the row's *active* PEs of EffI (lagging PEs first),
    SharedW_n likewise per column;
  * shared registers buffer ``Buf[Shared : Shared+R]``; a PE fires iff both
    offsets are < R, else it idles this cycle;
  * output-stationary accumulation; outputs written back once per tile.

SRAM accounting (the paper's MAPM numerator):
  * the shared-register window slides monotonically, so each *newly covered*
    compressed element is fetched from SRAM exactly once (elements skipped by
    a window jump are never fetched);
  * output write-back: 1 byte per output (matches the paper's dense 4×4
    example accounting: 32 reads + 16 writes / 64 MACs = 0.75 B/MAC);
  * bitmap reads for EIM are tracked separately (``bitmap_bytes``).

The simulator also *computes the actual products* so correctness of the whole
EIM+SIDR pipeline is checked against a dense matmul in the tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.eim import EimStreams, eim_streams


@dataclasses.dataclass
class SidrStats:
    """Aggregate statistics of one simulate() call (batch of tiles)."""

    macs: int                 # non-zero MACs executed
    cycles: int               # sum over tiles of per-tile cycles
    max_cycles: int           # slowest tile (array executes tiles serially)
    input_bytes: int          # SRAM reads of compressed input values
    weight_bytes: int         # SRAM reads of compressed weight values
    output_bytes: int         # SRAM writes of outputs
    bitmap_bytes: int         # SRAM reads of bitmaps for EIM (reported aside)
    register_bytes: int       # shared-register fetches (2 per MAC)
    idle_pe_cycles: int       # PE-cycles spent idling (offset >= R)
    deadlock_breaks: int      # direct-fetch fallbacks (should be ~0)
    num_pes: int              # PEs in the array (M*N)
    outputs: np.ndarray | None = None  # (..., M, N) accumulators

    @property
    def sram_bytes(self) -> int:
        return self.input_bytes + self.weight_bytes + self.output_bytes

    @property
    def mapm(self) -> float:
        """Memory Access per MAC, bytes/MAC (paper's indicator)."""
        return self.sram_bytes / max(self.macs, 1)

    @property
    def utilization(self) -> float:
        return self.macs / max(self.cycles * self.num_pes, 1)

    def merge(self, other: "SidrStats") -> "SidrStats":
        assert self.num_pes == other.num_pes
        return SidrStats(
            macs=self.macs + other.macs,
            cycles=self.cycles + other.cycles,
            max_cycles=max(self.max_cycles, other.max_cycles),
            input_bytes=self.input_bytes + other.input_bytes,
            weight_bytes=self.weight_bytes + other.weight_bytes,
            output_bytes=self.output_bytes + other.output_bytes,
            bitmap_bytes=self.bitmap_bytes + other.bitmap_bytes,
            register_bytes=self.register_bytes + other.register_bytes,
            idle_pe_cycles=self.idle_pe_cycles + other.idle_pe_cycles,
            deadlock_breaks=self.deadlock_breaks + other.deadlock_breaks,
            num_pes=self.num_pes,
            outputs=None,
        )


def simulate(bmi: np.ndarray, bmw: np.ndarray,
             vi: np.ndarray | None = None, vw: np.ndarray | None = None,
             nnz_i: np.ndarray | None = None, nnz_w: np.ndarray | None = None,
             reg_size: int = 8, compute_values: bool = False) -> SidrStats:
    """Simulate SIDR for a batch of tiles.

    bmi: (..., M, K) bool input bitmaps;  bmw: (..., N, K) bool weight bitmaps.
    vi:  (..., M, P) packed compressed input values (P >= max nnz), optional;
    vw:  (..., N, Q) packed compressed weight values.
    Every (m, n) PE computes the sparse dot product of row m and column n.
    """
    bmi = np.asarray(bmi, bool)
    bmw = np.asarray(bmw, bool)
    if (vi is None) != (vw is None):
        raise ValueError(
            "simulate() needs vi and vw together (got "
            f"vi={'set' if vi is not None else None}, "
            f"vw={'set' if vw is not None else None}); pass both packed "
            "value arrays or neither")
    streams: EimStreams = eim_streams(bmi, bmw)
    *lead, m, n, lmax = streams.eff_i.shape
    lead = tuple(lead)
    unbatched = not lead
    if unbatched:
        lead = (1,)
        streams = EimStreams(streams.eff_i[None], streams.eff_w[None],
                             streams.length[None])
        bmi, bmw = bmi[None], bmw[None]
        if vi is not None:
            vi, vw = vi[None], vw[None]
    t = int(np.prod(lead))
    eff_i = streams.eff_i.reshape(t, m, n, lmax)
    eff_w = streams.eff_w.reshape(t, m, n, lmax)
    length = streams.length.reshape(t, m, n)
    if nnz_i is None:
        nnz_i = bmi.sum(-1)
    if nnz_w is None:
        nnz_w = bmw.sum(-1)
    nnz_i = np.asarray(nnz_i).reshape(t, m).astype(np.int64)
    nnz_w = np.asarray(nnz_w).reshape(t, n).astype(np.int64)

    compute_values = compute_values and vi is not None
    if compute_values:
        vi = np.asarray(vi).reshape(t, m, -1)
        vw = np.asarray(vw).reshape(t, n, -1)
        acc = np.zeros((t, m, n), np.float64)
    else:
        acc = None

    INF = np.int64(EimStreams.INVALID)
    ptr = np.zeros((t, m, n), np.int64)
    done = ptr >= length                      # PEs with empty FIFOs are done
    tile_alive = ~done.reshape(t, -1).all(-1)

    cycles = np.zeros(t, np.int64)
    idle_pe_cycles = 0
    deadlock_breaks = 0
    input_hi = np.zeros((t, m), np.int64)     # high-water mark of fetched elems
    weight_hi = np.zeros((t, n), np.int64)
    input_bytes = np.zeros(t, np.int64)
    weight_bytes = np.zeros(t, np.int64)
    register_bytes = 0

    ar_t = np.arange(t)[:, None, None]
    ar_m = np.arange(m)[None, :, None]
    ar_n = np.arange(n)[None, None, :]

    guard = 0
    max_guard = int(lmax) * m * n + 16
    while tile_alive.any():
        guard += 1
        if guard > max_guard:  # pragma: no cover - safety net
            raise RuntimeError("SIDR simulator failed to converge")
        active = ~done
        # -- pop/peek current effective pair (idle PEs retry the same pair)
        cur_p = np.minimum(ptr, length - 1)
        ei = np.where(active, eff_i[ar_t, ar_m, ar_n, cur_p], INF)
        ew = np.where(active, eff_w[ar_t, ar_m, ar_n, cur_p], INF)
        # -- shared indexes: min over the row / column's active PEs
        shared_i = ei.min(axis=2)             # (t, m)
        shared_w = ew.min(axis=1)             # (t, n)
        off_i = ei - shared_i[:, :, None]
        off_w = ew - shared_w[:, None, :]
        fire = active & (off_i < reg_size) & (off_w < reg_size)

        # -- deadlock break: no PE of an alive tile can fire -> let the PE
        # with the smallest combined offset fetch directly from SRAM.
        fired_any = fire.reshape(t, -1).any(-1)
        stuck = tile_alive & ~fired_any
        if stuck.any():
            comb = np.where(active, off_i + off_w, INF)
            flat = comb.reshape(t, -1)
            pick = flat.argmin(-1)
            s_idx = np.nonzero(stuck)[0]
            fire[s_idx, pick[s_idx] // n, pick[s_idx] % n] = True
            deadlock_breaks += int(stuck.sum())
            input_bytes[s_idx] += 1
            weight_bytes[s_idx] += 1

        # -- SRAM fetch accounting: newly covered window elements
        row_active = active.any(2)
        hi_new = np.minimum(shared_i + reg_size, nnz_i)
        lo_new = np.maximum(input_hi, np.minimum(shared_i, nnz_i))
        loads = np.where(row_active, np.maximum(hi_new - lo_new, 0), 0)
        input_bytes += loads.sum(1)
        input_hi = np.maximum(input_hi, np.where(row_active, hi_new, 0))

        col_active = active.any(1)
        hi_new_w = np.minimum(shared_w + reg_size, nnz_w)
        lo_new_w = np.maximum(weight_hi, np.minimum(shared_w, nnz_w))
        loads_w = np.where(col_active, np.maximum(hi_new_w - lo_new_w, 0), 0)
        weight_bytes += loads_w.sum(1)
        weight_hi = np.maximum(weight_hi, np.where(col_active, hi_new_w, 0))

        # -- execute MACs
        if compute_values:
            f_t, f_m, f_n = np.nonzero(fire)
            prod = (vi[f_t, f_m, ei[f_t, f_m, f_n]].astype(np.float64)
                    * vw[f_t, f_n, ew[f_t, f_m, f_n]])
            np.add.at(acc, (f_t, f_m, f_n), prod)
        register_bytes += 2 * int(fire.sum())
        idle_pe_cycles += int((active & ~fire).sum())

        ptr = ptr + fire
        done = ptr >= length
        cycles += tile_alive
        tile_alive = ~done.reshape(t, -1).all(-1)

    macs = int(length.sum())
    outputs = acc.reshape(*lead, m, n) if compute_values else None
    if compute_values and unbatched:
        outputs = outputs[0]
    return SidrStats(
        macs=macs,
        cycles=int(cycles.sum()),
        max_cycles=int(cycles.max()) if t else 0,
        input_bytes=int(input_bytes.sum()),
        weight_bytes=int(weight_bytes.sum()),
        output_bytes=t * m * n,
        bitmap_bytes=t * (m + n) * ((bmi.shape[-1] + 7) // 8),
        register_bytes=register_bytes,
        idle_pe_cycles=idle_pe_cycles,
        deadlock_breaks=deadlock_breaks,
        num_pes=m * n,
        outputs=outputs,
    )
