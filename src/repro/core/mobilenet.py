"""MobileNetV2 pointwise (1×1) layer inventory — paper §III-A workload.

The paper evaluates every PW layer of MobileNetV2 (ImageNet, 224×224) with
75 % global-L1 weight pruning.  A 1×1 convolution over a (H, W, Cin) tensor
is exactly the GEMM (M=H·W, K=Cin) × (K, N=Cout).

Activation sparsity: expand PW layers consume the *linear bottleneck* output
(no ReLU) → dense inputs; project PW layers and the final 1×1280 conv consume
ReLU6 outputs → sparse inputs.  Without ImageNet in this container the
post-ReLU6 sparsity is synthesised per layer (default 45 %, the
commonly-reported MobileNetV2 mid-network range); this is recorded in
EXPERIMENTS.md as a deviation.
"""
from __future__ import annotations

import dataclasses
from typing import List

# (expansion t, out channels c, repeats n, stride s) — Sandler et al., Table 2
_IR_BLOCKS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


@dataclasses.dataclass
class PwLayer:
    name: str
    m: int            # H_out * W_out
    k: int            # Cin
    n: int            # Cout
    input_relu: bool  # True -> input follows ReLU6 (sparse activations)

    @property
    def gemm_macs(self) -> int:
        return self.m * self.k * self.n


def pw_layers(input_size: int = 224) -> List[PwLayer]:
    layers: List[PwLayer] = []
    h = input_size // 2          # first 3x3 s2 conv -> 112
    cin = 32
    idx = 0
    for t, c, reps, s in _IR_BLOCKS:
        for r in range(reps):
            stride = s if r == 0 else 1
            h_out = h // stride
            if t != 1:
                # expand PW runs at the *input* resolution, dense input
                layers.append(PwLayer(f"pw{idx}_expand", h * h, cin,
                                      cin * t, input_relu=False))
            # project PW runs at the output resolution, post-ReLU6 input
            layers.append(PwLayer(f"pw{idx}_project", h_out * h_out,
                                  cin * t, c, input_relu=True))
            cin, h = c, h_out
            idx += 1
    layers.append(PwLayer("pw_head_1280", h * h, cin, 1280, input_relu=True))
    return layers
