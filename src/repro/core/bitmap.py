"""Bitmap sparse format (paper Fig. 1).

A vector/matrix is stored as (bitmap, compressed values): the bitmap marks
non-zero positions in original order; values are the non-zeros packed densely
("inside buffer" in the paper).  All simulator-side code is numpy (the
accelerator model runs on the host); jnp variants used by the framework live
in ``repro.sparse``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class BitmapVector:
    """One buffer row: bitmap over original indexes + packed non-zero values."""

    bitmap: np.ndarray  # (K,) bool
    values: np.ndarray  # (nnz,) packed non-zeros in original order

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    @property
    def k(self) -> int:
        return int(self.bitmap.shape[0])

    def decompress(self) -> np.ndarray:
        out = np.zeros(self.k, dtype=self.values.dtype)
        out[self.bitmap] = self.values
        return out


def compress(x: np.ndarray) -> BitmapVector:
    """Compress a 1-D vector to bitmap format."""
    x = np.asarray(x)
    bitmap = x != 0
    return BitmapVector(bitmap=bitmap, values=x[bitmap])


def compress_rows(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compress each row of a 2-D matrix.

    Returns (bitmap (M, K) bool, values (M, max_nnz) zero-padded,
    nnz (M,) int32).  Padded layout keeps the simulator fully vectorised.
    """
    x = np.asarray(x)
    bitmap = x != 0
    nnz = bitmap.sum(axis=-1).astype(np.int32)
    max_nnz = int(nnz.max()) if x.size else 0
    m, k = x.shape
    values = np.zeros((m, max(max_nnz, 1)), dtype=x.dtype)
    # rank of each non-zero inside its row = its compressed index
    ranks = np.cumsum(bitmap, axis=-1) - 1
    rows, cols = np.nonzero(bitmap)
    values[rows, ranks[rows, cols]] = x[rows, cols]
    return bitmap, values, nnz


def mask_index(bitmap: np.ndarray) -> np.ndarray:
    """Paper's IMId / WMId: original index of each compressed element.

    ``mask_index(bm)[j]`` = original position of the j-th non-zero.  Rows with
    fewer non-zeros are padded with K (out of range sentinel).
    """
    bitmap = np.asarray(bitmap, dtype=bool)
    if bitmap.ndim == 1:
        return np.nonzero(bitmap)[0]
    m, k = bitmap.shape
    nnz = bitmap.sum(-1)
    out = np.full((m, int(nnz.max()) if m else 0), k, dtype=np.int64)
    ranks = np.cumsum(bitmap, axis=-1) - 1
    rows, cols = np.nonzero(bitmap)
    out[rows, ranks[rows, cols]] = cols
    return out


def random_sparse(shape, sparsity: float, rng: np.random.Generator,
                  dtype=np.float32) -> np.ndarray:
    """Dense array with ~``sparsity`` fraction of exact zeros (unstructured)."""
    dense = rng.standard_normal(shape).astype(dtype)
    mask = rng.random(shape) >= sparsity
    return dense * mask


def prune_global_l1(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Global L1 fine-grained magnitude pruning (Han et al. [1], as in paper)."""
    if sparsity <= 0:
        return w
    flat = np.abs(w).ravel()
    k = int(round(sparsity * flat.size))
    if k <= 0:
        return w
    thresh = np.partition(flat, k - 1)[k - 1]
    return np.where(np.abs(w) <= thresh, 0.0, w).astype(w.dtype)
