"""28 nm event-level energy model — reproduces paper Table I / Figs. 8-9.

Per-event energies follow Horowitz (ISSCC'14, 45 nm) scaled to 28 nm
(~0.55× dynamic energy), the technology the paper synthesises in:

    8-bit multiply          0.12 pJ  (45nm: 0.2 pJ)
    24-bit accumulate add   0.06 pJ
    SRAM read/write         ~0.7 pJ/byte (8-16 KB macro)
    register/MUX fetch      0.03 pJ/byte
    EIM matching logic      0.05 pJ per matched pair (bitmap AND + re-sort
                            amortised over the row/col share)

The model's purpose is *relative* dataflow comparison (ours vs SparTen-style
vs SCNN-style): energy ratios are dominated by the SRAM-traffic term the
paper optimises.  A fixed overhead share (clock tree, FIFOs, control) is
calibrated so the dense-utilisation operating point reproduces the paper's
2.066 TOPS/W; the *sparse* operating point (66 % utilisation) then follows
from counted events — reproducing ≈1.2 TOPS/W is a model validation, not an
input.
"""
from __future__ import annotations

import dataclasses

from repro.core.sidr import SidrStats

PJ = 1e-12

E_MULT8 = 0.12 * PJ
E_ADD24 = 0.06 * PJ
E_MAC = E_MULT8 + E_ADD24
E_SRAM_BYTE = 0.70 * PJ
E_REG_BYTE = 0.03 * PJ
E_EIM_PAIR = 0.05 * PJ
# static/control energy per PE-cycle (clock tree, FIFO regs, idle PEs) —
# calibrated once against Table I's dense operating point (2.066 TOPS/W).
E_CYCLE_PE = 0.045 * PJ

CLOCK_HZ = 800e6
NUM_MACS = 256  # 16x16 array


@dataclasses.dataclass
class EnergyReport:
    mac_j: float
    sram_j: float
    register_j: float
    eim_j: float
    control_j: float

    @property
    def total_j(self) -> float:
        return (self.mac_j + self.sram_j + self.register_j + self.eim_j
                + self.control_j)

    def breakdown(self) -> dict:
        t = self.total_j
        return {
            "MAC": self.mac_j / t,
            "SRAM buffer": self.sram_j / t,
            "Shared registers": self.register_j / t,
            "EIM": self.eim_j / t,
            "Control/clock": self.control_j / t,
        }


def energy_from_stats(stats: SidrStats) -> EnergyReport:
    """Energy of one simulated workload under the event model."""
    return EnergyReport(
        mac_j=stats.macs * E_MAC,
        sram_j=(stats.sram_bytes + stats.bitmap_bytes) * E_SRAM_BYTE,
        register_j=stats.register_bytes * E_REG_BYTE,
        eim_j=stats.macs * E_EIM_PAIR,
        control_j=stats.cycles * stats.num_pes * E_CYCLE_PE,
    )


def energy_dataflow(macs: int, sram_bytes: float, cycles: float,
                    num_pes: int = NUM_MACS) -> float:
    """Energy (J) of a generic dataflow given its event counts.

    Used for SparTen/SCNN-style comparisons where we have analytic byte
    counts instead of a cycle simulation; register traffic is folded into the
    2 B/MAC operand fetches those dataflows already pay.
    """
    return (macs * (E_MAC + E_EIM_PAIR) + sram_bytes * E_SRAM_BYTE
            + cycles * num_pes * E_CYCLE_PE)


def tops_per_watt(macs: int, energy_j: float) -> float:
    """TOPS/W counting only non-zero ops (SIGMA's rigorous accounting);
    1 MAC = 2 ops."""
    return (2.0 * macs / energy_j) / 1e12


def power_watts(energy_j: float, cycles: int) -> float:
    return energy_j / (cycles / CLOCK_HZ)
