"""Effective Index Matching (EIM) — paper §II-C, Fig. 4.

EIM converts (input bitmap, weight bitmap) into per-PE streams of
*effective indexes* (EffI, EffW): positions of the two operands of every
non-zero multiplication inside the **compressed** buffers, emitted in
original-index order.  These streams feed the per-PE ``EIM_FIFO``s consumed
by the SIDR dataflow (``repro.core.sidr``).

Two implementations are provided and tested for equivalence:

* ``eim_reference`` — the intuitive masking method the paper describes first
  (mask BMNZ with BMI/BMW then re-sort) — direct but "not hardware efficient".
* ``eim_streams`` — the paper's two-step method using mask indexes
  (IMId/WMId) and masked bitmaps (IMBM/WMBM), fully vectorised; this is what
  the simulator and the Pallas decompression kernels mirror.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.bitmap import mask_index


@dataclasses.dataclass
class EimStreams:
    """Padded per-PE FIFO contents for a (M rows × N cols) tile.

    eff_i / eff_w : (M, N, L) int32 — compressed-buffer indexes per non-zero
        multiplication, in original-index order; padded with ``INVALID``.
    length        : (M, N) int32 — number of valid entries (= # non-zero MACs).
    """

    eff_i: np.ndarray
    eff_w: np.ndarray
    length: np.ndarray

    INVALID = np.int32(2**30)


def eim_reference(bmi: np.ndarray, bmw: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Naive single-PE EIM: returns (eff_i, eff_w) 1-D streams.

    bmi, bmw: (K,) bool bitmaps of one input row and one weight column.
    """
    bmi = np.asarray(bmi, bool)
    bmw = np.asarray(bmw, bool)
    bmnz = bmi & bmw
    pos = np.nonzero(bmnz)[0]
    rank_i = np.cumsum(bmi) - 1  # original idx -> compressed idx
    rank_w = np.cumsum(bmw) - 1
    return rank_i[pos].astype(np.int32), rank_w[pos].astype(np.int32)


def eim_two_step(bmi: np.ndarray, bmw: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """The paper's hardware method for one PE.

    Step 1: mask indexes IMId/WMId (original index of each compressed slot) —
    shared by the whole row/column of PEs in hardware.
    Step 2: gather BMNZ at the mask indexes -> masked bitmaps IMBM/WMBM over
    compressed slots; the set positions *are* the effective indexes, and both
    masked bitmaps enumerate the same non-zero ops in the same (original
    index) order, so zipping them pairs the operands.
    """
    bmi = np.asarray(bmi, bool)
    bmw = np.asarray(bmw, bool)
    bmnz = bmi & bmw
    im_id = mask_index(bmi)          # (nnz_i,) original index per slot
    wm_id = mask_index(bmw)
    imbm = bmnz[im_id]               # which compressed input slots are used
    wmbm = bmnz[wm_id]
    eff_i = np.nonzero(imbm)[0].astype(np.int32)
    eff_w = np.nonzero(wmbm)[0].astype(np.int32)
    assert eff_i.shape == eff_w.shape
    return eff_i, eff_w


def eim_streams(bmi: np.ndarray, bmw: np.ndarray) -> EimStreams:
    """Vectorised EIM for a full tile.

    bmi: (M, K) bool — input bitmaps of the M rows (shared along PE rows).
    bmw: (N, K) bool — weight bitmaps of the N columns (shared along cols).

    Leading batch dimensions are supported: bmi (..., M, K), bmw (..., N, K)
    with identical leading shape.
    """
    bmi = np.asarray(bmi, bool)
    bmw = np.asarray(bmw, bool)
    *lead, m, k = bmi.shape
    n = bmw.shape[-2]

    bmnz = bmi[..., :, None, :] & bmw[..., None, :, :]       # (..., M, N, K)
    length = bmnz.sum(-1).astype(np.int32)                    # (..., M, N)
    lmax = max(int(length.max()) if length.size else 0, 1)

    order = np.cumsum(bmnz, axis=-1, dtype=np.int32) - 1      # rank of each op
    rank_i = (np.cumsum(bmi, -1, dtype=np.int32) - 1)[..., :, None, :]
    rank_w = (np.cumsum(bmw, -1, dtype=np.int32) - 1)[..., None, :, :]

    shape = tuple(lead) + (m, n, lmax)
    eff_i = np.full(shape, EimStreams.INVALID, np.int32)
    eff_w = np.full(shape, EimStreams.INVALID, np.int32)
    idx = np.nonzero(bmnz)
    slot = idx[:-1] + (order[idx],)
    eff_i[slot] = np.broadcast_to(rank_i, bmnz.shape)[idx]
    eff_w[slot] = np.broadcast_to(rank_w, bmnz.shape)[idx]
    return EimStreams(eff_i=eff_i, eff_w=eff_w, length=length)
