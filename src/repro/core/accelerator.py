"""Whole-accelerator model: tiled sparse GEMM on the 16×16 SIDR array.

Maps an (M,K)×(K,N) GEMM onto the PE array: 16-row × 16-column output tiles,
K split into SRAM-buffer-sized chunks, output-stationary across K chunks
(accumulators persist in the PEs, so outputs hit SRAM once).  Per-tile
behaviour comes from the cycle-accurate SIDR simulator; this module
aggregates cycles / SRAM traffic / energy and derives the paper's metrics
(MAPM, utilisation, speed-up vs dense, TOPS/W).

Large GEMMs are statistically homogeneous across row tiles, so the simulator
can subsample row tiles (``max_row_tiles``) and scale the counts — used by
the benchmarks to keep single-core runtime sane; exact mode is the default.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import energy as energy_model
from repro.core.mapm import (DataflowCounts, scnn, sparten, sparse_macs,
                             SPARTEN_PAPER_MAPM)
from repro.core.bitmap import compress_rows
from repro.core.sidr import SidrStats, simulate


@dataclasses.dataclass
class AcceleratorConfig:
    array_m: int = 16
    array_n: int = 16
    reg_size: int = 8
    k_buffer: int = 4096       # K elements resident per pass (SRAM capacity)
    tile_batch: int = 64       # tiles simulated per vectorised batch


@dataclasses.dataclass
class GemmReport:
    m: int
    n: int
    k: int
    stats: SidrStats
    dense_cycles: int
    sparten_counts: DataflowCounts
    scnn_counts: DataflowCounts
    sampled_fraction: float = 1.0
    outputs: np.ndarray | None = None

    @property
    def mapm(self) -> float:
        return self.stats.mapm

    @property
    def utilization(self) -> float:
        return self.stats.utilization

    @property
    def speedup_vs_dense(self) -> float:
        return self.dense_cycles / max(self.stats.cycles, 1)

    @property
    def sram_reduction_vs_sparten(self) -> float:
        return 1.0 - self.mapm / SPARTEN_PAPER_MAPM

    @property
    def energy(self) -> energy_model.EnergyReport:
        return energy_model.energy_from_stats(self.stats)

    @property
    def tops_per_watt(self) -> float:
        return energy_model.tops_per_watt(self.stats.macs, self.energy.total_j)

    def summary(self) -> dict:
        return {
            "shape": (self.m, self.n, self.k),
            "macs": self.stats.macs,
            "cycles": self.stats.cycles,
            "mapm": round(self.mapm, 4),
            "utilization": round(self.utilization, 4),
            "speedup_vs_dense": round(self.speedup_vs_dense, 3),
            "sram_reduction_vs_sparten": round(
                self.sram_reduction_vs_sparten, 4),
            "sparten_mapm": round(self.sparten_counts.mapm, 4),
            "scnn_mapm": round(self.scnn_counts.mapm, 4),
            "tops_per_watt": round(self.tops_per_watt, 4),
            "deadlock_breaks": self.stats.deadlock_breaks,
        }


def _pad_rows(x: np.ndarray, tile: int) -> np.ndarray:
    m = x.shape[0]
    pad = (-m) % tile
    if pad:
        x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
    return x


def run_gemm(x: np.ndarray, w: np.ndarray,
             cfg: AcceleratorConfig | None = None,
             compute_values: bool = False,
             max_row_tiles: int | None = None,
             seed: int = 0) -> GemmReport:
    """Execute O = X @ W^T on the modelled accelerator.

    x: (M, K) activations, w: (N, K) weights (possibly pruned to zeros).
    """
    cfg = cfg or AcceleratorConfig()
    x = np.asarray(x)
    w = np.asarray(w)
    m, k = x.shape
    n = w.shape[0]
    assert w.shape[1] == k

    xp = _pad_rows(x, cfg.array_m)
    wp = _pad_rows(w, cfg.array_n)
    tm = xp.shape[0] // cfg.array_m
    tn = wp.shape[0] // cfg.array_n

    rng = np.random.default_rng(seed)
    row_tiles = np.arange(tm)
    sampled_fraction = 1.0
    if max_row_tiles is not None and tm > max_row_tiles:
        row_tiles = np.sort(rng.choice(tm, size=max_row_tiles, replace=False))
        sampled_fraction = max_row_tiles / tm

    x_tiles = xp.reshape(tm, cfg.array_m, k)[row_tiles]
    w_tiles = wp.reshape(tn, cfg.array_n, k)

    n_chunks = -(-k // cfg.k_buffer)
    total: SidrStats | None = None
    outputs = (np.zeros((len(row_tiles) * cfg.array_m, wp.shape[0]))
               if compute_values else None)

    pairs = [(i, j) for i in range(len(row_tiles)) for j in range(tn)]
    for c in range(n_chunks):
        k0, k1 = c * cfg.k_buffer, min((c + 1) * cfg.k_buffer, k)
        bx, vx, nx = compress_rows(
            x_tiles[:, :, k0:k1].reshape(-1, k1 - k0))
        bw, vw, nw = compress_rows(
            w_tiles[:, :, k0:k1].reshape(-1, k1 - k0))
        bx = bx.reshape(len(row_tiles), cfg.array_m, -1)
        vx = vx.reshape(len(row_tiles), cfg.array_m, -1)
        nx = nx.reshape(len(row_tiles), cfg.array_m)
        bw = bw.reshape(tn, cfg.array_n, -1)
        vw = vw.reshape(tn, cfg.array_n, -1)
        nw = nw.reshape(tn, cfg.array_n)

        for b0 in range(0, len(pairs), cfg.tile_batch):
            batch = pairs[b0:b0 + cfg.tile_batch]
            bi = np.array([p[0] for p in batch])
            bj = np.array([p[1] for p in batch])
            stats = simulate(
                bx[bi], bw[bj], vx[bi] if compute_values else None,
                vw[bj] if compute_values else None,
                nnz_i=nx[bi], nnz_w=nw[bj],
                reg_size=cfg.reg_size, compute_values=compute_values)
            if compute_values:
                for t_idx, (ti, tj) in enumerate(batch):
                    r0 = ti * cfg.array_m
                    c0 = tj * cfg.array_n
                    outputs[r0:r0 + cfg.array_m,
                            c0:c0 + cfg.array_n] += stats.outputs[t_idx]
            stats.outputs = None
            total = stats if total is None else total.merge(stats)

    # outputs hit SRAM once per (row,col) tile pair, not once per K chunk
    total.output_bytes = len(row_tiles) * cfg.array_m * tn * cfg.array_n
    dense_cycles = len(row_tiles) * tn * k

    if sampled_fraction < 1.0:
        scale = 1.0 / sampled_fraction
        total = SidrStats(
            macs=int(total.macs * scale),
            cycles=int(total.cycles * scale),
            max_cycles=total.max_cycles,
            input_bytes=int(total.input_bytes * scale),
            weight_bytes=int(total.weight_bytes * scale),
            output_bytes=int(total.output_bytes * scale),
            bitmap_bytes=int(total.bitmap_bytes * scale),
            register_bytes=int(total.register_bytes * scale),
            idle_pe_cycles=int(total.idle_pe_cycles * scale),
            deadlock_breaks=total.deadlock_breaks,
            num_pes=total.num_pes,
        )
        dense_cycles = int(dense_cycles / sampled_fraction)

    bx_full = x != 0
    bw_full = w != 0
    nnz_macs = total.macs
    sparten_counts = sparten(nnz_macs, m * n)
    scnn_counts = scnn(nnz_macs, int(bx_full.sum()) * 1, int(bw_full.sum()))

    if compute_values:
        full = np.zeros((xp.shape[0], wp.shape[0]))
        for t_idx, ti in enumerate(row_tiles):
            full[ti * cfg.array_m:(ti + 1) * cfg.array_m] = outputs[
                t_idx * cfg.array_m:(t_idx + 1) * cfg.array_m]
        outputs = full[:m, :n]

    return GemmReport(m=m, n=n, k=k, stats=total, dense_cycles=dense_cycles,
                      sparten_counts=sparten_counts, scnn_counts=scnn_counts,
                      sampled_fraction=sampled_fraction, outputs=outputs)
