"""Faithful reproduction of the paper's accelerator: EIM + SIDR + MAPM.

Layers:
  bitmap      — bitmap sparse format (Fig. 1) + global-L1 pruning
  eim         — Effective Index Matching (§II-C, Fig. 4)
  sidr        — cycle-level SIDR simulator of Algorithm 1 (16×16 PE array)
  mapm        — Memory-Access-per-MAC analytics + SparTen/SCNN/dense baselines
  energy      — 28 nm event-level energy model (Table I, Figs. 8-9)
  accelerator — tiled GEMM → PE array aggregation (speed-up, TOPS/W)
  mobilenet   — MobileNetV2 PW-layer workload inventory (§III-A)
"""
from repro.core.accelerator import AcceleratorConfig, GemmReport, run_gemm
from repro.core.bitmap import (BitmapVector, compress, compress_rows,
                               mask_index, prune_global_l1, random_sparse)
from repro.core.eim import EimStreams, eim_reference, eim_streams, eim_two_step
from repro.core.mapm import (dense_output_stationary, reduction_vs_sparten,
                             scnn, sparse_macs, sparten)
from repro.core.sidr import SidrStats, simulate

__all__ = [
    "AcceleratorConfig", "GemmReport", "run_gemm", "BitmapVector", "compress",
    "compress_rows", "mask_index", "prune_global_l1", "random_sparse",
    "EimStreams", "eim_reference", "eim_streams", "eim_two_step",
    "dense_output_stationary", "reduction_vs_sparten", "scnn", "sparse_macs",
    "sparten", "SidrStats", "simulate",
]
