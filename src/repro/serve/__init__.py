"""Continuous-batching sparse serving engine (queue, slots, KV reuse,
paged KV cache, chunked batched prefill, whole-stack bitmap weight
streaming, request-lifecycle hardening + fault injection)."""
from repro.serve.cache import SlotKVCache
from repro.serve.engine import ServeEngine, pack_lm_head
from repro.serve.errors import (AuditViolation, DeadlineExceeded,
                                ServeError, ServeOverloaded)
from repro.serve.faults import Fault, FaultPlan, InvariantAuditor
from repro.serve.packed import (PackedModel, PackEntry, choose_block,
                                pack_model)
from repro.serve.paging import (OutOfPages, PagedKVCache, PagePool,
                                PrefixBlock)
from repro.serve.prefill import PrefillJob, PrefillPlanner
from repro.serve.request import (Request, RequestRejected, RequestState,
                                 TERMINAL_STATES)
from repro.serve.scheduler import SlotScheduler
from repro.serve.telemetry import (ChromeTrace, Clock, Counter, EventLog,
                                   Gauge, Histogram, MetricsRegistry,
                                   StepSpans, Telemetry, load_trace,
                                   validate_events, validate_trace)
from repro.serve.trace import RollingStat, percentiles, poisson_trace
from repro.serve.traffic import TrafficLedger, role_of

__all__ = [
    "AuditViolation", "ChromeTrace", "Clock", "Counter",
    "DeadlineExceeded", "EventLog", "Fault", "FaultPlan", "Gauge",
    "Histogram", "InvariantAuditor", "MetricsRegistry", "OutOfPages",
    "PackEntry", "PackedModel", "PagePool", "PagedKVCache", "PrefillJob",
    "PrefillPlanner", "PrefixBlock", "Request", "RequestRejected",
    "RequestState", "RollingStat", "ServeEngine", "ServeError",
    "ServeOverloaded", "SlotKVCache", "SlotScheduler", "StepSpans",
    "TERMINAL_STATES", "Telemetry", "TrafficLedger", "choose_block",
    "load_trace", "pack_lm_head", "pack_model", "percentiles",
    "poisson_trace", "role_of", "validate_events", "validate_trace",
]
