"""Continuous-batching sparse serving engine (queue, slots, KV reuse,
whole-stack bitmap weight streaming)."""
from repro.serve.cache import SlotKVCache
from repro.serve.engine import ServeEngine, pack_lm_head
from repro.serve.packed import (PackedModel, PackEntry, choose_block,
                                pack_model)
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import SlotScheduler
from repro.serve.trace import percentiles, poisson_trace

__all__ = [
    "PackEntry", "PackedModel", "Request", "RequestState", "ServeEngine",
    "SlotKVCache", "SlotScheduler", "choose_block", "pack_lm_head",
    "pack_model", "percentiles", "poisson_trace",
]
