"""Continuous-batching sparse serving engine (queue, slots, KV reuse)."""
from repro.serve.cache import SlotKVCache
from repro.serve.engine import ServeEngine, pack_lm_head
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import SlotScheduler
from repro.serve.trace import percentiles, poisson_trace

__all__ = [
    "Request", "RequestState", "ServeEngine", "SlotKVCache", "SlotScheduler",
    "pack_lm_head", "percentiles", "poisson_trace",
]
