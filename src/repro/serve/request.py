"""Serving request state.

A ``Request`` carries everything the engine needs across its lifetime:
the prompt, the generation budget, the arrival offset (measured in decode
steps so traces are deterministic regardless of host speed), and the
timing marks the benchmark turns into latency percentiles.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence


class RequestRejected(ValueError):
    """A request the engine can *never* serve (empty prompt, or a
    prompt + budget that exceeds ``max_len`` / the whole page pool).

    Typed so serving processes can refuse one oversized request and keep
    running — the old ``assert`` killed the process.  Requests that
    merely have to wait for capacity (a full batch, or an exhausted page
    pool under paging) are never rejected; they queue until slots or
    pages free up.
    """


class RequestState(enum.Enum):
    WAITING = "waiting"     # submitted, not yet admitted to a slot
    ACTIVE = "active"       # owns a batch slot, decoding
    DONE = "done"           # generation budget exhausted, slot released


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival: float = 0.0            # decode-step offset at which it arrives
    temperature: float = 0.0        # 0 = greedy; > 0 samples logits / T
    seed: Optional[int] = None      # per-request sampling stream (None:
    #                                 engine derives one from the rid)
    top_k: Optional[int] = None     # per-request top-k truncation (None:
    #                                 engine default; 0 = no truncation)

    # -- filled in by the engine --
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None      # last slot owned (kept after release)
    state: RequestState = RequestState.WAITING
    admit_step: Optional[int] = None
    done_step: Optional[int] = None
    t_due: Optional[float] = None   # wall time the arrival offset was reached
    t_admit: Optional[float] = None  # wall time a slot was granted
    t_prefill_done: Optional[float] = None  # wall time the prompt cache was
    #                                 resident (last prefill chunk, or the
    #                                 last teacher-forced prompt step)
    t_first: Optional[float] = None  # wall time of the first generated token
    t_done: Optional[float] = None   # wall time generation finished
    t_preempt: List[float] = dataclasses.field(default_factory=list)
    #                                 wall times this request was preempted
    #                                 (pages reclaimed, re-queued, its
    #                                 prefix later recomputed)
    prefix_hit_tokens: int = 0       # prompt tokens adopted from the
    #                                 shared-prefix cache (prefill skipped)
    recomputed_tokens: int = 0       # positions re-ingested after
    #                                 preemption (recompute cost)

    @property
    def latency_s(self) -> Optional[float]:
        """Queue + decode wall latency (arrival -> last token)."""
        if self.t_due is None or self.t_done is None:
            return None
        return self.t_done - self.t_due

    @property
    def first_token_s(self) -> Optional[float]:
        """Total TTFT (arrival -> first generated token) — the sum of the
        queue / prefill / first-decode components below."""
        if self.t_due is None or self.t_first is None:
            return None
        return self.t_first - self.t_due

    @property
    def queue_s(self) -> Optional[float]:
        """Arrival -> slot granted: pure queueing, no compute."""
        if self.t_due is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_due

    @property
    def prefill_s(self) -> Optional[float]:
        """Slot granted -> prompt cache resident (chunked prefill calls,
        or the one-token-per-step teacher-forced walk in legacy mode)."""
        if self.t_admit is None or self.t_prefill_done is None:
            return None
        return self.t_prefill_done - self.t_admit

    @property
    def first_decode_s(self) -> Optional[float]:
        """Prompt resident -> first generated token (the first real
        decode step, including any wait for its turn in the batch)."""
        if self.t_prefill_done is None or self.t_first is None:
            return None
        return self.t_first - self.t_prefill_done
