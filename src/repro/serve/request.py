"""Serving request state.

A ``Request`` carries everything the engine needs across its lifetime:
the prompt, the generation budget, the arrival offset (measured in decode
steps so traces are deterministic regardless of host speed), and the
timing marks the benchmark turns into latency percentiles.

Lifecycle: every request ends in exactly one terminal state —

  DONE        generation budget exhausted, all tokens delivered
  CANCELLED   client called ``engine.cancel(rid)``; partial tokens kept
  EXPIRED     ``deadline_ms`` elapsed (measured from arrival-due);
              ``DeadlineExceeded`` recorded, partial tokens kept
  SHED        admission control refused it under overload;
              ``ServeOverloaded`` recorded, no tokens

``transition()`` enforces the legal state machine (audited per step when
the engine runs with ``audit=True``), and ``result()`` gives callers the
tokens-or-typed-error view of the outcome.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Set

from repro.serve.errors import (AuditViolation, RequestRejected, ServeError)

__all__ = ["Request", "RequestRejected", "RequestState"]


class RequestState(enum.Enum):
    WAITING = "waiting"     # submitted, not yet admitted to a slot
    ACTIVE = "active"       # owns a batch slot, decoding
    DONE = "done"           # generation budget exhausted, slot released
    CANCELLED = "cancelled"  # client-cancelled (queued or mid-flight)
    EXPIRED = "expired"     # deadline_ms elapsed before completion
    SHED = "shed"           # refused by admission control under overload


#: Terminal states — once entered, no further transition is legal.
TERMINAL_STATES: Set[RequestState] = {
    RequestState.DONE, RequestState.CANCELLED, RequestState.EXPIRED,
    RequestState.SHED,
}

#: The legal request-state machine.  WAITING -> WAITING is allowed so
#: (re)enqueueing an already-waiting request stays idempotent;
#: ACTIVE -> WAITING is the preemption requeue edge.
_TRANSITIONS: Dict[RequestState, Set[RequestState]] = {
    RequestState.WAITING: {RequestState.WAITING, RequestState.ACTIVE,
                           RequestState.CANCELLED, RequestState.EXPIRED,
                           RequestState.SHED},
    RequestState.ACTIVE: {RequestState.DONE, RequestState.WAITING,
                          RequestState.CANCELLED, RequestState.EXPIRED},
    RequestState.DONE: set(),
    RequestState.CANCELLED: set(),
    RequestState.EXPIRED: set(),
    RequestState.SHED: set(),
}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Sequence[int]
    max_new_tokens: int
    arrival: float = 0.0            # decode-step offset at which it arrives
    temperature: float = 0.0        # 0 = greedy; > 0 samples logits / T
    seed: Optional[int] = None      # per-request sampling stream (None:
    #                                 engine derives one from the rid)
    top_k: Optional[int] = None     # per-request top-k truncation (None:
    #                                 engine default; 0 = no truncation)
    deadline_ms: Optional[float] = None  # latency budget measured from the
    #                                 moment the arrival offset comes due
    #                                 (None: engine default / no deadline)

    # -- filled in by the engine --
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None      # last slot owned (kept after release)
    state: RequestState = RequestState.WAITING
    error: Optional[ServeError] = None  # typed terminal error (EXPIRED /
    #                                 SHED); None for DONE and CANCELLED
    admit_step: Optional[int] = None
    done_step: Optional[int] = None
    t_due: Optional[float] = None   # wall time the arrival offset was reached
    t_admit: Optional[float] = None  # wall time a slot was granted
    t_prefill_done: Optional[float] = None  # wall time the prompt cache was
    #                                 resident (last prefill chunk, or the
    #                                 last teacher-forced prompt step)
    t_first: Optional[float] = None  # wall time of the first generated token
    t_done: Optional[float] = None   # wall time generation finished
    t_preempt: List[float] = dataclasses.field(default_factory=list)
    #                                 wall times this request was preempted
    #                                 (pages reclaimed, re-queued, its
    #                                 prefix later recomputed)
    prefix_hit_tokens: int = 0       # prompt tokens adopted from the
    #                                 shared-prefix cache (prefill skipped)
    recomputed_tokens: int = 0       # positions re-ingested after
    #                                 preemption (recompute cost)

    def transition(self, new: RequestState) -> None:
        """Move to ``new``, enforcing the legal state machine."""
        if new not in _TRANSITIONS[self.state]:
            raise AuditViolation(
                f"illegal request-state transition {self.state.value} -> "
                f"{new.value} (rid {self.rid})")
        self.state = new

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def result(self) -> List[int]:
        """Generated tokens, or raise this request's typed terminal
        error (``DeadlineExceeded`` / ``ServeOverloaded``).  Cancelled
        requests return their partial tokens — the client asked."""
        if self.error is not None:
            raise self.error
        return list(self.tokens)

    @property
    def latency_s(self) -> Optional[float]:
        """Queue + decode wall latency (arrival -> last token)."""
        if self.t_due is None or self.t_done is None:
            return None
        return self.t_done - self.t_due

    @property
    def first_token_s(self) -> Optional[float]:
        """Total TTFT (arrival -> first generated token) — the sum of the
        queue / prefill / first-decode components below."""
        if self.t_due is None or self.t_first is None:
            return None
        return self.t_first - self.t_due

    @property
    def queue_s(self) -> Optional[float]:
        """Arrival -> slot granted: pure queueing, no compute."""
        if self.t_due is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_due

    @property
    def prefill_s(self) -> Optional[float]:
        """Slot granted -> prompt cache resident (chunked prefill calls,
        or the one-token-per-step teacher-forced walk in legacy mode)."""
        if self.t_admit is None or self.t_prefill_done is None:
            return None
        return self.t_prefill_done - self.t_admit

    @property
    def first_decode_s(self) -> Optional[float]:
        """Prompt resident -> first generated token (the first real
        decode step, including any wait for its turn in the batch)."""
        if self.t_prefill_done is None or self.t_first is None:
            return None
        return self.t_first - self.t_prefill_done

    def timeline(self):
        """Lifecycle as trace rows: ``(spans, instants)`` where spans is
        ``[(name, t_begin, t_end), ...]`` over QUEUED / PREFILL / DECODE
        and instants marks each preemption.  Tolerant of partial marks —
        an aborted request emits only the phases it reached, each closed
        at the latest timestamp it recorded."""
        marks = [t for t in (self.t_due, self.t_admit,
                             self.t_prefill_done, self.t_done)
                 if t is not None]
        if not marks:
            return [], []
        end = max(marks)
        spans = []
        if self.t_due is not None:
            spans.append(("QUEUED", self.t_due,
                          self.t_admit if self.t_admit is not None
                          else end))
        if self.t_admit is not None:
            spans.append(("PREFILL", self.t_admit,
                          self.t_prefill_done
                          if self.t_prefill_done is not None else end))
        if self.t_prefill_done is not None:
            spans.append(("DECODE", self.t_prefill_done,
                          self.t_done if self.t_done is not None
                          else end))
        instants = [("preempt", t) for t in self.t_preempt]
        return spans, instants
