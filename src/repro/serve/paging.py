"""Paged KV-cache subsystem: page pools, a free-list allocator, and
per-slot page tables for the serving engine.

The contiguous ``SlotKVCache`` reserves worst-case ``num_slots × max_len``
KV lines per attention leaf for the engine's lifetime, so short requests
pay long-request storage.  This module regularises that last irregular
consumer the same way the paper's SIDR regularises sparse operand
fetches — into fixed-size shared units gathered through an index:

* each attention block gets a **pool** of physical pages, shape
  ``(P, pool_pages, page_len, Hkv, hd)`` (axis 0 is the period stack, so
  one logical page id covers all periods of the block);
* each batch slot gets a **page table** of ``page_slots =
  ceil(capacity / page_len)`` int32 entries per pool (capacity is
  window-bounded for sliding-window blocks), mapping logical token slots
  onto physical pages;
* a host-side **free-list allocator** hands out pages lazily as a slot's
  position advances and takes them back when the request retires — the
  pool (what is actually reserved) scales with *live tokens*, not
  ``num_slots × max_len``.

Physical page 0 of every pool is a reserved **trash page**: unmapped
table entries point at it, so idle batch slots — which still execute the
decode step's cache write at position 0 — scribble into the trash line
instead of someone else's live page, and gathers of not-yet-written
logical pages read garbage that the attention validity mask always
excludes.  Pages therefore never need zeroing between requests; only the
O(1)-per-slot recurrent (SSM/RWKV) state is zeroed on admission.

Admission is commitment-based so allocation can never fail mid-flight:
a request commits its worst-case page count per pool
(``ceil((len(prompt) + max_new_tokens - 1) / page_len)``, ring-capped at
``page_slots``) when admitted, and the engine only admits while every
pool has ``committed + candidate <= pool_pages``.  Since a slot never
maps more pages than it committed, the free list is provably non-empty
whenever ``ensure`` needs a page (tests/test_paging.py property-checks
this along with no-double-free, no cross-slot aliasing and free-list
conservation).  Out-of-pages is thus an *admission* condition — the
request waits in the queue until retirements free pages — never a crash.

Invariants (property-tested in tests/test_paging.py):

* **Pages are never zeroed** — the validity mask in
  ``layers.decode_attention`` (``slot_pos <= pos``, window bound)
  excludes stale gathers, so a page handed from one request to another
  needs no scrub; only O(1)-per-slot recurrent state is zeroed.
* **A live page has exactly one writer** — its owning slot.  Idle or
  masked-off lanes resolve to physical page 0 (the trash page), which
  is reserved and never allocated.
* **The free list is conserved and non-empty on demand** — a page is
  free xor mapped by exactly one slot; commitments bound mapped pages,
  so ``ensure``/``ensure_range`` cannot run dry mid-flight.
* **Addressing is single-sourced** — ``model.paged_addressing`` defines
  (capacity, ring) once for the host allocator and the device cache
  write, so they cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import (attn_capacity, init_cache,
                                paged_addressing, paged_layout)


@dataclasses.dataclass
class PagePool:
    """Host-side allocator state for one attention block's page pool."""

    bname: str
    capacity: int          # per-slot logical capacity in tokens (no pad)
    page_slots: int        # page-table width = ceil(capacity / page_len)
    pool_pages: int        # allocatable data pages (trash page excluded)
    window: Optional[int]  # sliding-window size (None = full attention)
    ring: bool             # sliding-window ring addressing (mod capacity)
    line_bytes: int        # K+V bytes of one token line across periods
    free: List[int] = dataclasses.field(default_factory=list)
    table: Optional[np.ndarray] = None   # (num_slots, page_slots) int32
    committed: int = 0     # admission-reserved worst-case pages
    in_use: int = 0
    peak: int = 0


class PagedKVCache:
    """Drop-in cache manager for ``ServeEngine`` with paged attention KV.

    Mirrors ``SlotKVCache``'s surface (``cache``, ``resets``, ``warmup``)
    and adds the allocator: ``possible``/``fits`` for admission control,
    ``admit``/``ensure``/``retire`` for the page lifecycle, ``tables()``
    for the per-step jit argument, and ``report()`` for the paging
    section of the engine report.

    ``pool_tokens`` bounds each pool to ``ceil(pool_tokens / page_len)``
    data pages (capped at the worst case ``num_slots * page_slots``);
    default is the worst case, which still allocates lazily but can
    always admit whatever the contiguous cache could.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 page_len: int, pool_tokens: Optional[int] = None):
        assert page_len > 0
        layout = paged_layout(cfg, max_len, page_len)
        if not layout:
            raise ValueError(f"{cfg.name}: no attention blocks to page")
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_len = page_len
        self.resets = 0

        kv_line = (2 * cfg.num_periods * cfg.num_kv_heads
                   * cfg.resolved_head_dim
                   * jnp.dtype(cfg.compute_dtype).itemsize)
        budget = (-(-pool_tokens // page_len)
                  if pool_tokens is not None else None)
        self.pools: Dict[str, PagePool] = {}
        for i, blk in enumerate(cfg.pattern):
            bname = f"b{i}"
            if bname not in layout:
                continue
            slots = layout[bname]
            _, ring = paged_addressing(slots, page_len, blk.window)
            worst = num_slots * slots
            pages = worst if budget is None else max(1, min(budget, worst))
            pool = PagePool(
                bname=bname, capacity=attn_capacity(blk, max_len),
                page_slots=slots, pool_pages=pages, window=blk.window,
                ring=ring, line_bytes=kv_line)
            # page ids 1..pool_pages; id 0 is the trash page
            pool.free = list(range(pages, 0, -1))
            pool.table = np.zeros((num_slots, slots), np.int32)
            self.pools[bname] = pool

        pool_pages = {b: p.pool_pages + 1 for b, p in self.pools.items()}
        self.cache = init_cache(cfg, num_slots, max_len, page_len=page_len,
                                pool_pages=pool_pages)
        self._commit: List[Dict[str, int]] = [
            {} for _ in range(num_slots)]
        # device-side table cache: mappings change on a handful of steps
        # per request (admit / page boundary / retire), so the hot decode
        # loop reuses one upload until a mutation invalidates it
        self._dev_tables: Optional[Dict[str, jnp.ndarray]] = None
        # jitted donated reset for the slotted (non-paged) leaves only:
        # recurrent state is zeroed per admission, page pools never are
        # (the k/v leaves of paged blocks pass through untouched; any
        # slotted sibling leaf — e.g. cm_x_prev — still zeroes its line)
        paged_names = set(self.pools)

        def _reset_fn(cache, slot):
            return {b: {k: (v if (b in paged_names and k in ("k", "v"))
                            else v.at[:, slot].set(0))
                        for k, v in leaf.items()}
                    for b, leaf in cache.items()}

        self._reset = jax.jit(_reset_fn, donate_argnums=(0,))

    # ------------------------------------------------------- admission ----

    def pages_for(self, need_tokens: int) -> Dict[str, int]:
        """Worst-case pages per pool for a request touching positions
        ``0 .. need_tokens-1`` (ring pools cap at their table width)."""
        n = -(-max(need_tokens, 1) // self.page_len)
        return {b: min(n, p.page_slots) for b, p in self.pools.items()}

    def possible(self, need_tokens: int) -> bool:
        """Can this request ever be admitted (empty engine)?"""
        return all(n <= self.pools[b].pool_pages
                   for b, n in self.pages_for(need_tokens).items())

    def fits(self, need_tokens: int) -> bool:
        """Can this request be admitted *now* without risking mid-flight
        page exhaustion for anyone already committed?"""
        return all(self.pools[b].committed + n <= self.pools[b].pool_pages
                   for b, n in self.pages_for(need_tokens).items())

    def reserve(self, need_tokens: int) -> bool:
        """Check-and-commit in one step — the scheduler's admission gate.

        Commits the worst-case pages immediately on success, so several
        admissions in one scheduler pass can't all pass a stale check
        and over-commit the pool.  ``admit`` then binds the reservation
        to its slot without counting it again.
        """
        if not self.fits(need_tokens):
            return False
        for b, n in self.pages_for(need_tokens).items():
            self.pools[b].committed += n
        return True

    def admit(self, slot: int, need_tokens: int) -> None:
        """Bind a prior ``reserve`` to its slot, zero the slot's
        recurrent state, and map the first page (position 0 is written
        on the admit step)."""
        assert 0 <= slot < self.num_slots
        assert not self._commit[slot], f"slot {slot} not retired"
        self._commit[slot] = self.pages_for(need_tokens)
        self.cache = self._reset(self.cache, jnp.int32(slot))
        self.resets += 1
        self.ensure(slot, 0)

    def _map_page(self, bname: str, pool: PagePool, slot: int,
                  pi: int) -> None:
        """Map one logical page-table entry, allocating off the free list
        (no-op when already mapped)."""
        if pool.table[slot, pi] == 0:
            assert pool.free, (
                f"{bname}: free list empty with {pool.committed} committed "
                f"of {pool.pool_pages} — commitment invariant broken")
            pool.table[slot, pi] = pool.free.pop()
            pool.in_use += 1
            pool.peak = max(pool.peak, pool.in_use)
            self._dev_tables = None

    def ensure(self, slot: int, pos: int) -> None:
        """Map the page holding ``pos``'s write slot, allocating lazily.

        Shares the device-side addressing with ``_decode_attn`` through
        ``models.model.paged_addressing``: ring pools write at
        ``pos % cap``, others clip to the last slot.
        """
        for b, pool in self.pools.items():
            cap, ring = paged_addressing(pool.page_slots, self.page_len,
                                         pool.window)
            wslot = pos % cap if ring else min(max(pos, 0), cap - 1)
            self._map_page(b, pool, slot, wslot // self.page_len)

    def ensure_range(self, slot: int, start: int, end: int) -> None:
        """Bulk-map every page a chunk touching positions
        ``start .. end-1`` will write — chunked prefill's one-admission
        analogue of per-step ``ensure``: all of the chunk's pages are
        mapped before the prefill call, so the device-side scatter never
        meets an unmapped live position.

        Same addressing as ``ensure``; ring pools that wrap within the
        range simply map their whole table (a ring never needs more than
        ``page_slots`` pages).
        """
        if end <= start:
            return
        for b, pool in self.pools.items():
            cap, ring = paged_addressing(pool.page_slots, self.page_len,
                                         pool.window)
            if ring and end - start >= cap:
                pis = range(pool.page_slots)
            else:
                pis = {(p % cap if ring else min(max(p, 0), cap - 1))
                       // self.page_len for p in range(start, end)}
            for pi in sorted(pis):
                self._map_page(b, pool, slot, pi)

    def retire(self, slot: int) -> None:
        """Return the slot's pages to the free list and uncommit."""
        self._dev_tables = None
        for b, pool in self.pools.items():
            row = pool.table[slot]
            mapped = [int(p) for p in row[row != 0]]
            assert not set(mapped) & set(pool.free), "double free"
            pool.free.extend(mapped)
            pool.in_use -= len(mapped)
            row[:] = 0
            pool.committed -= self._commit[slot].get(b, 0)
        self._commit[slot] = {}

    # ------------------------------------------------------------ step ----

    def tables(self) -> Dict[str, jnp.ndarray]:
        """Per-step jit argument: the current page tables, device-side
        (uploaded only after a mapping actually changed)."""
        if self._dev_tables is None:
            self._dev_tables = {b: jnp.asarray(p.table)
                                for b, p in self.pools.items()}
        return self._dev_tables

    def warmup(self) -> None:
        """Compile the slotted-state reset executable."""
        self.cache = self._reset(self.cache, jnp.int32(0))

    # --------------------------------------------------------- reports ----

    def reserved_kv_bytes(self) -> int:
        """Bytes actually reserved for KV pages (trash pages included)."""
        return sum((p.pool_pages + 1) * self.page_len * p.line_bytes
                   for p in self.pools.values())

    def contiguous_kv_bytes(self) -> int:
        """What the contiguous layout would reserve for the same engine."""
        return sum(self.num_slots * p.capacity * p.line_bytes
                   for p in self.pools.values())

    def report(self, positions: Optional[Sequence[int]] = None) -> Dict:
        """Paging stats: pages in use / peak / total, reserved vs
        contiguous modeled cache-HBM bytes, and — given the active slots'
        current positions — internal fragmentation (allocated-but-dead
        fraction of in-use page tokens)."""
        in_use = sum(p.in_use for p in self.pools.values())
        total = sum(p.pool_pages for p in self.pools.values())
        reserved = self.reserved_kv_bytes()
        contiguous = self.contiguous_kv_bytes()
        frag = None
        if positions is not None:
            alloc_tokens = live_tokens = 0
            for p in self.pools.values():
                alloc_tokens += p.in_use * self.page_len
                live_tokens += sum(min(pos + 1, p.capacity)
                                   for pos in positions)
            frag = (1.0 - live_tokens / alloc_tokens if alloc_tokens
                    else 0.0)
        return {
            "page_len": self.page_len,
            "pages_in_use": in_use,
            "pages_peak": sum(p.peak for p in self.pools.values()),
            "pages_total": total,
            "pools": {b: {"pages": p.pool_pages, "in_use": p.in_use,
                          "peak": p.peak, "page_slots": p.page_slots,
                          "ring": p.ring}
                      for b, p in self.pools.items()},
            "reserved_kv_bytes": reserved,
            "contiguous_kv_bytes": contiguous,
            "reserved_reduction": (contiguous / reserved if reserved
                                   else 1.0),
            "fragmentation": frag,
        }
