"""Paged KV-cache subsystem: page pools, a refcounted free-list
allocator, per-slot page tables, and a shared-prefix page cache for the
serving engine.

The contiguous ``SlotKVCache`` reserves worst-case ``num_slots × max_len``
KV lines per attention leaf for the engine's lifetime, so short requests
pay long-request storage.  This module regularises that last irregular
consumer the same way the paper's SIDR regularises sparse operand
fetches — into fixed-size shared units gathered through an index:

* each attention block gets a **pool** of physical pages, shape
  ``(P, pool_pages, page_len, Hkv, hd)`` (axis 0 is the period stack, so
  one logical page id covers all periods of the block);
* each batch slot gets a **page table** of ``page_slots =
  ceil(capacity / page_len)`` int32 entries per pool (capacity is
  window-bounded for sliding-window blocks), mapping logical token slots
  onto physical pages;
* a host-side **refcounted free-list allocator** hands out pages lazily
  as a slot's position advances and takes them back when the last
  reference drops — the pool (what is actually reserved) scales with
  *live tokens*, not ``num_slots × max_len``.

Physical page 0 of every pool is a reserved **trash page**: unmapped
table entries point at it, so idle batch slots — which still execute the
decode step's cache write at position 0 — scribble into the trash line
instead of someone else's live page, and gathers of not-yet-written
logical pages read garbage that the attention validity mask always
excludes.  Pages therefore never need zeroing between requests; only the
O(1)-per-slot recurrent (SSM/RWKV) state is zeroed on admission.

**Shared-prefix reuse (SIDR at the cache level).**  Requests that share
a system prompt share physical pages: the prefix cache hashes
``page_len``-token prompt blocks into a chain
(``sha1(parent_digest ‖ block_tokens)``) and keeps, per chain node, the
one physical page per pool holding that block's K/V lines.  A new
request whose prompt matches a cached chain *adopts* those pages
copy-on-write — every matched page's refcount is bumped and mapped into
the slot's tables, and prefill starts after the matched region (a full
hit skips prefill entirely).  Writes into a shared page (a sliding-
window ring wrapping back over the prefix) **fork** it first: a fresh
page is allocated, the page contents are copied device-side, and the
writer's table entry is swapped, so every other holder (other slots,
the cache itself) keeps the original bytes.  Chains are capped at the
smallest pool capacity (``shareable_tokens``) so no ring ever wraps
*inside* a shared prefix — within that region, logical block ``i``
lives in table entry ``i`` of every pool and its page holds exactly
that block's tokens.

Admission is commitment-based so allocation can never fail mid-flight
in strict mode: a request commits its worst-case page count per pool
(``ceil((len(prompt) + max_new_tokens - 1) / page_len)``, ring-capped at
``page_slots``) when admitted, and the engine only admits while every
pool has ``committed + candidate <= pool_pages``.  Since a slot never
allocates more pages than it committed (a COW fork of an adopted entry
replaces the adoption, so per-entry allocations stay <= 1), the free
list is provably non-empty whenever ``ensure`` needs a page once cache-
only pages are evicted.  With ``strict=False`` (the engine's
recompute-on-preempt mode) commitments shrink to the *live* ingest need
and ``ensure`` may instead raise ``OutOfPages`` — the engine resolves it
by evicting cached prefixes and, if still dry, preempting the youngest
slot.  Out-of-pages is thus an *admission or preemption* condition —
never a crash.

Invariants (property-tested in tests/test_paging.py and
tests/test_prefix_reuse.py):

* **Pages are never zeroed** — the validity mask in
  ``layers.decode_attention`` (``slot_pos <= pos``, window bound)
  excludes stale gathers, so a page handed from one request to another
  needs no scrub; only O(1)-per-slot recurrent state is zeroed.
* **A live page has exactly one writer** — COW forks guarantee it: a
  write lands in a page only while its refcount is exactly 1 (idle or
  masked-off lanes resolve to the reserved trash page 0).
* **Free xor referenced** — every data page id is on the free list xor
  has refcount >= 1, and a page's refcount equals the number of slot
  table entries mapping it plus one if a prefix-cache block holds it
  (no double free, conservation: ``len(free) + referenced ==
  pool_pages`` after every transition).
* **Capped admission on both sides** — ``possible()``/``fits()`` and
  the bound commitments all go through ``pages_for``'s per-pool
  ``min(need_pages, page_slots)`` cap, so a sliding-window request
  longer than its window is neither spuriously rejected nor
  over-committed (the ring never touches more than its table width).
* **Addressing is single-sourced** — ``model.paged_addressing`` defines
  (capacity, ring) once for the host allocator and the device cache
  write, so they cannot drift.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import (attn_capacity, init_cache,
                                paged_addressing, paged_layout)
from repro.serve.errors import AuditViolation, OutOfPages

__all__ = ["OutOfPages", "PagePool", "PagedKVCache", "PrefixBlock"]


@dataclasses.dataclass
class PagePool:
    """Host-side allocator state for one attention block's page pool."""

    bname: str
    capacity: int          # per-slot logical capacity in tokens (no pad)
    page_slots: int        # page-table width = ceil(capacity / page_len)
    pool_pages: int        # allocatable data pages (trash page excluded)
    window: Optional[int]  # sliding-window size (None = full attention)
    ring: bool             # sliding-window ring addressing (mod capacity)
    line_bytes: int        # K+V bytes of one token line across periods
    free: List[int] = dataclasses.field(default_factory=list)
    ref: Dict[int, int] = dataclasses.field(default_factory=dict)
    table: Optional[np.ndarray] = None   # (num_slots, page_slots) int32
    committed: int = 0     # admission-reserved worst-case pages
    in_use: int = 0        # pages off the free list (any refcount)
    peak: int = 0
    held: List[int] = dataclasses.field(default_factory=list)
    #                      # fault-injection: pages confiscated from the
    #                      # free list (neither free nor referenced)
    shards: int = 1        # data-axis shard count (1 = classic layout)
    shard_pages: int = 0   # allocatable data pages per shard
    committed_by: List[int] = dataclasses.field(default_factory=list)
    #                      # per-shard committed pages (sums to committed)


@dataclasses.dataclass
class PrefixBlock:
    """One cached ``page_len``-token prefix block: a node in the hash
    chain holding one physical page per pool.  The cache itself counts
    as one reference on each page, so registered pages survive their
    writer's retirement and later requests can adopt them."""

    key: bytes                     # sha1(parent_digest || block tokens)
    parent: Optional[bytes]        # previous block in the chain
    index: int                     # block index == table entry == page i
    length: int                    # tokens covered: (index + 1) * page_len
    pages: Dict[str, int]          # bname -> physical page id
    children: int = 0              # cached blocks extending this one
    shard: int = 0                 # owning shard (pages are shard-local)


def _chain_key(parent: Optional[bytes], tokens: Sequence[int]) -> bytes:
    h = hashlib.sha1(parent or b"")
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.digest()


class PagedKVCache:
    """Drop-in cache manager for ``ServeEngine`` with paged attention KV.

    Mirrors ``SlotKVCache``'s surface (``cache``, ``resets``, ``warmup``)
    and adds the allocator: ``possible``/``fits`` for admission control,
    ``admit``/``ensure``/``retire`` for the page lifecycle, the prefix
    cache (``match_prefix``/``register_prefix``/``evict_one``),
    ``tables()`` for the per-step jit argument, and ``report()`` for the
    paging section of the engine report.

    ``pool_tokens`` bounds each pool to ``ceil(pool_tokens / page_len)``
    data pages (capped at the worst case ``num_slots * page_slots``);
    default is the worst case, which still allocates lazily but can
    always admit whatever the contiguous cache could.

    ``strict=True`` (default) keeps the commitment invariant: the free
    list can never run dry mid-flight, so ``ensure`` never raises.
    ``strict=False`` relaxes commitments to whatever the engine chooses
    to reserve; a dry free list then raises ``OutOfPages`` after the
    prefix cache is drained, and the engine preempts.

    ``shards > 1`` partitions slots into contiguous groups and each
    pool's page ids into per-shard ranges (each with its own trash
    page), matching a mesh data axis: a slot only ever maps pages of
    its own shard, eviction/commitment/fault headroom are per-shard,
    and prefix chains are shard-salted — so a PartitionSpec over the
    pages axis makes every slot's KV pages device-local while
    allocation stays host-side.  ``shards == 1`` is byte-identical to
    the classic layout.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 page_len: int, pool_tokens: Optional[int] = None,
                 strict: bool = True, shards: int = 1):
        assert page_len > 0
        assert 1 <= shards <= num_slots and num_slots % shards == 0, \
            (shards, num_slots)
        layout = paged_layout(cfg, max_len, page_len)
        if not layout:
            raise ValueError(f"{cfg.name}: no attention blocks to page")
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_len = page_len
        self.strict = strict
        self.resets = 0
        # data-axis sharding: slots partition into `shards` contiguous
        # groups; each shard owns a contiguous page-id range (its own
        # trash page included), so a PartitionSpec over the pages axis
        # keeps every slot's pages — and its trash writes — device-local
        self.shards = shards
        self._slot_shard = (np.arange(num_slots) * shards
                            // num_slots).astype(np.int64)

        kv_line = (2 * cfg.num_periods * cfg.num_kv_heads
                   * cfg.resolved_head_dim
                   * jnp.dtype(cfg.compute_dtype).itemsize)
        budget = (-(-pool_tokens // page_len)
                  if pool_tokens is not None else None)
        self.pools: Dict[str, PagePool] = {}
        for i, blk in enumerate(cfg.pattern):
            bname = f"b{i}"
            if bname not in layout:
                continue
            slots = layout[bname]
            _, ring = paged_addressing(slots, page_len, blk.window)
            # per-shard sizing: each shard serves num_slots/shards slots
            # out of its own page range, so the worst case and any
            # explicit budget divide by the shard count
            worst = (num_slots // shards) * slots
            per = (worst if budget is None
                   else max(1, min(-(-budget // shards), worst)))
            pool = PagePool(
                bname=bname, capacity=attn_capacity(blk, max_len),
                page_slots=slots, pool_pages=per * shards,
                window=blk.window, ring=ring, line_bytes=kv_line,
                shards=shards, shard_pages=per,
                committed_by=[0] * shards)
            # shard d owns ids d·(per+1)+1 .. d·(per+1)+per; id d·(per+1)
            # is shard d's trash page (shards == 1 reduces to the classic
            # layout: trash 0, data ids 1..pool_pages, LIFO pop from end)
            pool.free = [d * (per + 1) + pg
                         for d in range(shards - 1, -1, -1)
                         for pg in range(per, 0, -1)]
            pool.table = np.zeros((num_slots, slots), np.int32)
            self.pools[bname] = pool

        # shared prefixes are chain-capped at the smallest pool capacity
        # (padded), so no ring ever wraps *inside* a shared region and
        # logical block i == table entry i == page index i in every pool
        self.shareable_tokens = min(
            paged_addressing(p.page_slots, page_len, p.window)[0]
            for p in self.pools.values())
        self.prefix: "OrderedDict[bytes, PrefixBlock]" = OrderedDict()
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.hit_tokens = 0
        self.evictions = 0
        self.forks = 0

        pool_pages = {b: p.pool_pages + p.shards
                      for b, p in self.pools.items()}
        self.cache = init_cache(cfg, num_slots, max_len, page_len=page_len,
                                pool_pages=pool_pages)
        self._commit: List[Dict[str, int]] = [
            {} for _ in range(num_slots)]
        # device-side table cache: mappings change on a handful of steps
        # per request (admit / page boundary / retire), so the hot decode
        # loop reuses one upload until a mutation invalidates it
        self._dev_tables: Optional[Dict[str, jnp.ndarray]] = None
        # per-pool jitted COW page copy (src -> dst, donated): forks are
        # rare (a ring wrapping over a shared prefix), so each pool's
        # copy executable compiles once on first fork
        self._copy_fns: Dict[str, Callable] = {}
        # jitted donated reset for the slotted (non-paged) leaves only:
        # recurrent state is zeroed per admission, page pools never are
        # (the k/v leaves of paged blocks pass through untouched; any
        # slotted sibling leaf — e.g. cm_x_prev — still zeroes its line)
        paged_names = set(self.pools)

        def _reset_fn(cache, slot):
            return {b: {k: (v if (b in paged_names and k in ("k", "v"))
                            else v.at[:, slot].set(0))
                        for k, v in leaf.items()}
                    for b, leaf in cache.items()}

        self._reset = jax.jit(_reset_fn, donate_argnums=(0,))

    # ------------------------------------------------------- admission ----

    def pages_for(self, need_tokens: int) -> Dict[str, int]:
        """Worst-case pages per pool for a request touching positions
        ``0 .. need_tokens-1``.  Ring pools cap at their table width —
        positions past the window wrap onto already-counted entries, so
        the *unwrapped* token count never translates into more than
        ``page_slots`` pages.  Every admission-side check (``possible``,
        ``fits``, ``reserve``) and the bound commitment go through this
        one cap, so they cannot disagree."""
        n = -(-max(need_tokens, 1) // self.page_len)
        return {b: min(n, p.page_slots) for b, p in self.pools.items()}

    def slot_shard(self, slot: int) -> int:
        """Which shard's page range ``slot`` allocates from."""
        return int(self._slot_shard[slot])

    def _page_shard(self, pool: PagePool, pg: int) -> int:
        """Owning shard of a physical page id (trash pages included)."""
        return pg // (pool.shard_pages + 1)

    def _shard_held(self, pool: PagePool, d: int) -> int:
        if pool.shards == 1:
            return len(pool.held)
        return sum(1 for pg in pool.held if self._page_shard(pool, pg) == d)

    def possible(self, need_tokens: int) -> bool:
        """Can this request ever be admitted (empty engine)?  Sharded
        pools admit out of one shard's range, so the bound is per-shard."""
        return all(n <= self.pools[b].shard_pages
                   for b, n in self.pages_for(need_tokens).items())

    def fits(self, need_tokens: int, slot: int = 0) -> bool:
        """Can this request be admitted *now* — into ``slot``'s shard —
        without risking mid-flight page exhaustion for anyone already
        committed there?  Confiscated (fault-held) pages shrink the
        usable shard until restored."""
        d = self.slot_shard(slot)
        return all(self.pools[b].committed_by[d] + n
                   <= self.pools[b].shard_pages
                   - self._shard_held(self.pools[b], d)
                   for b, n in self.pages_for(need_tokens).items())

    def reserve(self, need_tokens: int, slot: int = 0) -> bool:
        """Check-and-commit in one step — the scheduler's admission gate.

        Commits the pages immediately on success, so several admissions
        in one scheduler pass can't all pass a stale check and
        over-commit the pool.  ``admit`` then binds the reservation to
        its slot without counting it again (``slot`` must be the slot —
        or any same-shard slot — the scheduler will hand out, so the
        commitment lands in the right shard).  In strict mode the engine
        passes the worst-case need; in preemptible mode it passes the
        live ingest length, which is what lets occupancy rise at equal
        pool size.
        """
        if not self.fits(need_tokens, slot=slot):
            return False
        d = self.slot_shard(slot)
        for b, n in self.pages_for(need_tokens).items():
            self.pools[b].committed += n
            self.pools[b].committed_by[d] += n
        return True

    def admit(self, slot: int, need_tokens: int,
              prefix: Optional[List[PrefixBlock]] = None) -> int:
        """Bind a prior ``reserve`` to its slot, zero the slot's
        recurrent state, and adopt any matched prefix blocks copy-on-
        write.  Returns the number of adopted (prefill-skippable)
        tokens.  Nothing is *allocated* here — adoption only bumps
        refcounts — so admission itself can never hit ``OutOfPages``;
        the first allocation happens in ``ensure``/``ensure_range`` on
        the slot's first write."""
        assert 0 <= slot < self.num_slots
        assert not self._commit[slot], f"slot {slot} not retired"
        self._commit[slot] = self.pages_for(need_tokens)
        self.cache = self._reset(self.cache, jnp.int32(slot))
        self.resets += 1
        # prefix=None: reuse disabled (no hit/miss accounting);
        # prefix=[]: reuse enabled but nothing matched (a counted miss)
        return (self.adopt_prefix(slot, prefix)
                if prefix is not None else 0)

    # ------------------------------------------------------- allocator ----

    def _has_free(self, pool: PagePool, d: int) -> bool:
        if pool.shards == 1:
            return bool(pool.free)
        return any(self._page_shard(pool, pg) == d for pg in pool.free)

    def _pop_free(self, pool: PagePool, d: int) -> int:
        """Pop the most recently freed page of shard ``d`` (plain LIFO
        pop when unsharded)."""
        if pool.shards == 1:
            return pool.free.pop()
        for i in range(len(pool.free) - 1, -1, -1):
            if self._page_shard(pool, pool.free[i]) == d:
                return pool.free.pop(i)
        raise IndexError(f"shard {d}: no free page")

    def _alloc(self, bname: str, pool: PagePool, shard: int = 0) -> int:
        """Pop a fresh page off ``shard``'s free range (refcount 1),
        draining that shard's cache-only prefix pages first when dry."""
        while not self._has_free(pool, shard) and \
                self.evict_one(prefer=bname, shard=shard):
            pass
        if not self._has_free(pool, shard):
            if self.strict:
                raise AssertionError(
                    f"{bname}: shard {shard} free list empty with "
                    f"{pool.committed_by[shard]} committed of "
                    f"{pool.shard_pages} and no evictable "
                    f"prefix — commitment invariant broken")
            raise OutOfPages(bname)
        pg = self._pop_free(pool, shard)
        pool.ref[pg] = 1
        pool.in_use += 1
        pool.peak = max(pool.peak, pool.in_use)
        return pg

    def _deref(self, bname: str, pool: PagePool, pg: int) -> None:
        assert pg in pool.ref and pool.ref[pg] >= 1, \
            f"{bname}: double free of page {pg}"
        pool.ref[pg] -= 1
        if pool.ref[pg] == 0:
            del pool.ref[pg]
            pool.free.append(pg)
            pool.in_use -= 1

    def _fork(self, bname: str, pool: PagePool, slot: int,
              pi: int) -> None:
        """Copy-on-write: give ``slot`` a private copy of its shared
        table entry before it writes there.  Every other holder (other
        slots, the prefix cache) keeps the original page bytes."""
        src = int(pool.table[slot, pi])
        dst = self._alloc(bname, pool, self.slot_shard(slot))
        if bname not in self._copy_fns:
            def _copy(cache, s, d, _b=bname):
                leaf = dict(cache[_b])
                for kk in ("k", "v"):
                    leaf[kk] = leaf[kk].at[:, d].set(leaf[kk][:, s])
                return {**cache, _b: leaf}
            self._copy_fns[bname] = jax.jit(_copy, donate_argnums=(0,))
        self.cache = self._copy_fns[bname](self.cache, jnp.int32(src),
                                           jnp.int32(dst))
        pool.table[slot, pi] = dst
        self._deref(bname, pool, src)
        self.forks += 1
        self._dev_tables = None

    def _map_page(self, bname: str, pool: PagePool, slot: int,
                  pi: int) -> None:
        """Make table entry ``pi`` privately writable by ``slot``:
        allocate when unmapped, fork when shared, no-op when owned.

        A fork is only taken while a free page exists; with the list dry
        an eviction is tried first — evicting the cache's hold on this
        very page may drop its refcount to 1, resolving the share
        without any copy or allocation at all."""
        d = self.slot_shard(slot)
        pg = int(pool.table[slot, pi])
        if pg == 0:
            pool.table[slot, pi] = self._alloc(bname, pool, d)
            self._dev_tables = None
            return
        while pool.ref[pg] > 1:
            if not self._has_free(pool, d):
                if self.evict_one(prefer=bname, shard=d):
                    continue
                if self.strict:
                    raise AssertionError(
                        f"{bname}: shared page {pg} needs a fork but the "
                        f"pool is dry — commitment invariant broken")
                raise OutOfPages(bname)
            self._fork(bname, pool, slot, pi)
            return

    def ensure(self, slot: int, pos: int) -> None:
        """Make the page holding ``pos``'s write slot privately
        writable, allocating (or COW-forking a shared page) lazily.

        Shares the device-side addressing with ``_decode_attn`` through
        ``models.model.paged_addressing``: ring pools write at
        ``pos % cap``, others clip to the last slot.
        """
        for b, pool in self.pools.items():
            cap, ring = paged_addressing(pool.page_slots, self.page_len,
                                         pool.window)
            wslot = pos % cap if ring else min(max(pos, 0), cap - 1)
            self._map_page(b, pool, slot, wslot // self.page_len)

    def ensure_range(self, slot: int, start: int, end: int) -> None:
        """Bulk-map every page a chunk touching positions
        ``start .. end-1`` will write — chunked prefill's one-admission
        analogue of per-step ``ensure``: all of the chunk's pages are
        mapped (shared ones forked) before the prefill call, so the
        device-side scatter never meets an unmapped or shared live
        position.

        Same addressing as ``ensure``; pages map in first-touch position
        order (a per-step ensure walk over the same range produces the
        identical tables — property-tested), and a ring that wraps
        within the range maps its whole table in that order (a ring
        never needs more than ``page_slots`` pages).
        """
        if end <= start:
            return
        for b, pool in self.pools.items():
            cap, ring = paged_addressing(pool.page_slots, self.page_len,
                                         pool.window)
            span = range(start, min(end, start + cap) if ring else end)
            pis, seen = [], set()
            for p in span:
                pi = (p % cap if ring else min(max(p, 0), cap - 1)) \
                    // self.page_len
                if pi not in seen:
                    seen.add(pi)
                    pis.append(pi)
            for pi in pis:
                self._map_page(b, pool, slot, pi)

    def retire(self, slot: int) -> None:
        """Drop the slot's references and uncommit.  Pages whose last
        reference this was return to the free list; pages the prefix
        cache (or another slot) still holds stay resident — that is the
        whole point: the next request with the same prompt adopts them."""
        self._dev_tables = None
        d = self.slot_shard(slot)
        for b, pool in self.pools.items():
            row = pool.table[slot]
            for pg in [int(p) for p in row[row != 0]]:
                self._deref(b, pool, pg)
            row[:] = 0
            pool.committed -= self._commit[slot].get(b, 0)
            pool.committed_by[d] -= self._commit[slot].get(b, 0)
        self._commit[slot] = {}

    # ---------------------------------------------------- prefix cache ----

    def _chain(self, tokens: Sequence[int], upto: int,
               shard: int = 0) -> List[bytes]:
        """Chain keys for the fully-covered shareable blocks of
        ``tokens[:upto]``.  Chains are salted per shard (shard 0 keeps
        the classic keys), so a prompt cached in one shard's page range
        never matches — and never cross-shard-aliases — from another."""
        limit = min(upto, self.shareable_tokens)
        keys, parent = [], bytes([shard]) if shard else None
        for i in range(limit // self.page_len):
            parent = _chain_key(
                parent, tokens[i * self.page_len:(i + 1) * self.page_len])
            keys.append(parent)
        return keys

    def match_prefix(self, tokens: Sequence[int], slot: int = 0
                     ) -> Tuple[int, List[PrefixBlock]]:
        """Longest cached chain (in ``slot``'s shard) matching this
        prompt's leading blocks.

        Capped at ``len(tokens) - 1`` so the final prompt token always
        goes through the first decode step (which samples the first
        generated token), and at ``shareable_tokens``.  Matched entries
        are LRU-touched.  Returns ``(matched_tokens, blocks)``.
        """
        blocks: List[PrefixBlock] = []
        for key in self._chain(tokens, len(tokens) - 1,
                               self.slot_shard(slot)):
            entry = self.prefix.get(key)
            if entry is None:
                break
            self.prefix.move_to_end(key)
            blocks.append(entry)
        return len(blocks) * self.page_len, blocks

    def adopt_prefix(self, slot: int,
                     blocks: Sequence[PrefixBlock]) -> int:
        """Map matched prefix blocks into the slot's tables copy-on-
        write (refcount bumped per page; nothing is allocated).  The
        slot's tables must be freshly retired."""
        for e in blocks:
            for b, pg in e.pages.items():
                pool = self.pools[b]
                assert pool.table[slot, e.index] == 0, \
                    f"{b}: adopting into a mapped entry"
                pool.table[slot, e.index] = pg
                pool.ref[pg] += 1
        if blocks:
            self._dev_tables = None
            self.prefix_hits += 1
            self.hit_tokens += len(blocks) * self.page_len
        else:
            self.prefix_misses += 1
        return len(blocks) * self.page_len

    def register_prefix(self, slot: int, tokens: Sequence[int],
                        upto: int) -> None:
        """Publish the slot's fully-written leading blocks into the
        prefix cache (cache takes one reference per page).

        ``upto`` is the number of positions written so far — the engine
        calls this as prefill advances (before any later chunk can ring-
        wrap over a block) and on each legacy-walk block boundary.
        Blocks already cached are only LRU-touched; the chain stops at
        the first unregistrable entry so children always have cached
        parents.

        Registration past ``shareable_tokens`` is refused outright: once
        any position >= the smallest pool capacity has been written,
        that pool's ring has wrapped and the low table entries no longer
        hold their original blocks' lines (already-registered blocks are
        unaffected — the wrap's ``ensure`` forked them, the cache keeps
        the original page).  Calling this incrementally — after every
        prefill chunk / on every legacy-walk block boundary — is what
        keeps registration ahead of the wrap.
        """
        if upto > self.shareable_tokens:
            return
        shard = self.slot_shard(slot)
        parent: Optional[bytes] = None
        for i, key in enumerate(self._chain(tokens, upto, shard)):
            entry = self.prefix.get(key)
            if entry is not None:
                self.prefix.move_to_end(key)
                parent = key
                continue
            pages = {}
            for b, pool in self.pools.items():
                pg = int(pool.table[slot, i])
                if pg == 0:          # entry not written by this slot
                    return
                pages[b] = pg
            for b, pg in pages.items():
                self.pools[b].ref[pg] += 1
            self.prefix[key] = PrefixBlock(
                key=key, parent=parent, index=i,
                length=(i + 1) * self.page_len, pages=pages, shard=shard)
            if parent is not None:
                self.prefix[parent].children += 1
            parent = key

    def evict_one(self, prefer: Optional[str] = None,
                  shard: Optional[int] = None) -> bool:
        """Evict one leaf prefix block (LRU order), dropping the cache's
        page references.  ``prefer`` picks, among leaves, the oldest one
        whose page in that pool is cache-only (so eviction actually
        frees a page there); falls back to the oldest leaf.  ``shard``
        restricts to blocks owned by that shard (evicting another
        shard's block can never free a page the requester can use).
        Returns False when nothing is evictable."""
        chosen = None
        for key, e in self.prefix.items():
            if e.children:
                continue
            if shard is not None and e.shard != shard:
                continue
            if prefer is not None and self.pools[prefer].ref.get(
                    e.pages[prefer], 0) == 1:
                chosen = key
                break
            if chosen is None:
                chosen = key
                if prefer is None:
                    break
        if chosen is None:
            return False
        e = self.prefix.pop(chosen)
        if e.parent is not None and e.parent in self.prefix:
            self.prefix[e.parent].children -= 1
        for b, pg in e.pages.items():
            self._deref(b, self.pools[b], pg)
        self.evictions += 1
        return True

    # ------------------------------------------------- fault injection ----

    def confiscate(self, n: int) -> int:
        """Fault injection: pull up to ``n`` free pages per pool out of
        circulation (neither free nor referenced) to simulate pool
        exhaustion.  In strict mode only uncommitted headroom is taken —
        the commitment invariant (``ensure`` never fails) must survive
        any injected squeeze.  Returns the total pages held."""
        taken = 0
        for pool in self.pools.values():
            if self.strict:
                # per-shard headroom: the squeeze must not eat into any
                # shard's committed pages (shards == 1 reduces to the
                # classic pool-wide bound, same pages in the same order)
                room = [max(0, pool.shard_pages - pool.committed_by[d]
                            - self._shard_held(pool, d))
                        for d in range(pool.shards)]
            else:
                room = [len(pool.free)] * pool.shards
            took = 0
            i = len(pool.free) - 1
            while took < n and i >= 0:
                d = self._page_shard(pool, pool.free[i])
                if room[d] > 0:
                    room[d] -= 1
                    pool.held.append(pool.free.pop(i))
                    took += 1
                i -= 1
            taken += took
        return taken

    def restore_held(self) -> int:
        """Return every confiscated page to its free list.  Idempotent;
        returns the number of pages restored."""
        out = 0
        for pool in self.pools.values():
            out += len(pool.held)
            while pool.held:
                pool.free.append(pool.held.pop())
        return out

    def flush_prefix(self) -> int:
        """Evict the entire prefix cache (the eviction-storm fault, and
        the corruption-recovery hammer: published pages may hold bytes
        written through a corrupted weight path).  Returns the number of
        blocks evicted."""
        n = 0
        while self.evict_one():
            n += 1
        return n

    # ------------------------------------------------------------ audit ----

    def audit(self, commit_check: bool = True) -> None:
        """Full allocator invariant check (raises ``AuditViolation``):

        * refcount exactness: each page's refcount equals its table
          mappings plus one per prefix-cache hold;
        * free xor referenced (plus fault-held), no double free, and
          per-shard conservation: each shard's ``free + referenced +
          held == shard_pages`` (``pool_pages`` overall);
        * no table entry aliases any shard's trash page, every slot's
          pages live in its own shard's range (no cross-shard
          aliasing), and no two entries of the *same* slot map the same
          physical page;
        * commitment bookkeeping matches the per-slot reservations,
          per shard and overall.
        """
        for b, pool in self.pools.items():
            span = pool.shard_pages + 1       # shard range incl. trash
            refs: Dict[int, int] = {}
            for slot in range(self.num_slots):
                row = pool.table[slot]
                live = [int(p) for p in row[row != 0]]
                if len(live) != len(set(live)):
                    raise AuditViolation(
                        f"{b}: slot {slot} table aliases a page: {live}")
                d = self.slot_shard(slot)
                stray = [pg for pg in live
                         if self._page_shard(pool, pg) != d]
                if stray:
                    raise AuditViolation(
                        f"{b}: slot {slot} (shard {d}) maps pages from "
                        f"another shard: {stray}")
                for pg in live:
                    refs[pg] = refs.get(pg, 0) + 1
            for e in self.prefix.values():
                pg = e.pages[b]
                if self._page_shard(pool, pg) != e.shard:
                    raise AuditViolation(
                        f"{b}: prefix block of shard {e.shard} holds "
                        f"page {pg} of shard {self._page_shard(pool, pg)}")
                refs[pg] = refs.get(pg, 0) + 1
            if refs != pool.ref:
                drift = {pg: (refs.get(pg), pool.ref.get(pg))
                         for pg in set(refs) | set(pool.ref)
                         if refs.get(pg) != pool.ref.get(pg)}
                raise AuditViolation(f"{b}: refcount drift "
                                     f"(actual, recorded) = {drift}")
            free = pool.free
            if len(free) != len(set(free)):
                raise AuditViolation(f"{b}: duplicate free page")
            if set(free) & set(refs):
                raise AuditViolation(
                    f"{b}: page both free and referenced: "
                    f"{sorted(set(free) & set(refs))}")
            ids = set(free) | set(refs) | set(pool.held)
            if not all(0 < pg < pool.shards * span and pg % span != 0
                       for pg in ids):
                raise AuditViolation(
                    f"{b}: page id out of range (trash page leaked?)")
            for d in range(pool.shards):
                nf = sum(1 for pg in free
                         if self._page_shard(pool, pg) == d)
                nr = sum(1 for pg in refs
                         if self._page_shard(pool, pg) == d)
                nh = self._shard_held(pool, d)
                if nf + nr + nh != pool.shard_pages:
                    raise AuditViolation(
                        f"{b}: shard {d} conservation broken — {nf} free "
                        f"+ {nr} referenced + {nh} held "
                        f"!= {pool.shard_pages}")
            if pool.in_use != len(refs):
                raise AuditViolation(
                    f"{b}: in_use={pool.in_use} != {len(refs)} referenced")
            if commit_check:
                for d in range(pool.shards):
                    want = sum(c.get(b, 0)
                               for slot, c in enumerate(self._commit)
                               if self.slot_shard(slot) == d)
                    if pool.committed_by[d] != want:
                        raise AuditViolation(
                            f"{b}: shard {d} committed="
                            f"{pool.committed_by[d]} != {want} summed "
                            f"over slot reservations")
                    if pool.committed_by[d] > pool.shard_pages:
                        raise AuditViolation(
                            f"{b}: shard {d} over-committed "
                            f"{pool.committed_by[d]} of "
                            f"{pool.shard_pages}")
                if pool.committed != sum(pool.committed_by):
                    raise AuditViolation(
                        f"{b}: committed={pool.committed} != per-shard "
                        f"sum {sum(pool.committed_by)}")

    # ------------------------------------------------------------ step ----

    def tables(self) -> Dict[str, jnp.ndarray]:
        """Per-step jit argument: the current page tables, device-side
        (uploaded only after a mapping actually changed).  Sharded
        pools rewrite unmapped entries (host sentinel 0) to the slot's
        own shard's trash page, so idle-lane scribbles stay
        device-local (shard 0's trash *is* page 0)."""
        if self._dev_tables is None:
            if self.shards == 1:
                self._dev_tables = {b: jnp.asarray(p.table)
                                    for b, p in self.pools.items()}
            else:
                self._dev_tables = {}
                for b, p in self.pools.items():
                    trash = (self._slot_shard
                             * (p.shard_pages + 1)).astype(np.int32)
                    self._dev_tables[b] = jnp.asarray(
                        np.where(p.table == 0, trash[:, None], p.table))
        return self._dev_tables

    def warmup(self) -> None:
        """Compile the slotted-state reset executable."""
        self.cache = self._reset(self.cache, jnp.int32(0))

    # --------------------------------------------------------- reports ----

    def register_metrics(self, reg) -> None:
        """Expose allocator and prefix-cache counters as gauges."""
        reg.gauge("kv.resets", lambda: self.resets)
        reg.gauge("kv.reserved_bytes", self.reserved_kv_bytes)
        reg.gauge("paging.pages_in_use",
                  lambda: sum(p.in_use for p in self.pools.values()))
        reg.gauge("paging.pages_peak",
                  lambda: sum(p.peak for p in self.pools.values()))
        reg.gauge("paging.pages_total",
                  lambda: sum(p.pool_pages for p in self.pools.values()))
        reg.gauge("prefix.hits", lambda: self.prefix_hits)
        reg.gauge("prefix.misses", lambda: self.prefix_misses)
        reg.gauge("prefix.hit_tokens", lambda: self.hit_tokens)
        reg.gauge("prefix.evictions", lambda: self.evictions)
        reg.gauge("prefix.forks", lambda: self.forks)
        reg.gauge("prefix.cached_blocks", lambda: len(self.prefix))

    def reserved_kv_bytes(self) -> int:
        """Bytes actually reserved for KV pages (trash pages included —
        one per shard)."""
        return sum((p.pool_pages + p.shards) * self.page_len * p.line_bytes
                   for p in self.pools.values())

    def contiguous_kv_bytes(self) -> int:
        """What the contiguous layout would reserve for the same engine."""
        return sum(self.num_slots * p.capacity * p.line_bytes
                   for p in self.pools.values())

    def prefix_report(self) -> Dict:
        """Shared-prefix cache stats for the engine report."""
        lookups = self.prefix_hits + self.prefix_misses
        return {
            "cached_blocks": len(self.prefix),
            "cached_tokens": len(self.prefix) * self.page_len,
            "shareable_tokens": self.shareable_tokens,
            "hits": self.prefix_hits,
            "misses": self.prefix_misses,
            "hit_rate": (self.prefix_hits / lookups if lookups else None),
            "hit_tokens": self.hit_tokens,
            "evictions": self.evictions,
            "forks": self.forks,
        }

    def report(self, positions: Optional[Sequence[int]] = None) -> Dict:
        """Paging stats: pages in use / peak / total, reserved vs
        contiguous modeled cache-HBM bytes, and — given the active slots'
        current positions — internal fragmentation (allocated-but-dead
        fraction of in-use page tokens)."""
        in_use = sum(p.in_use for p in self.pools.values())
        total = sum(p.pool_pages for p in self.pools.values())
        reserved = self.reserved_kv_bytes()
        contiguous = self.contiguous_kv_bytes()
        frag = None
        if positions is not None:
            alloc_tokens = live_tokens = 0
            for p in self.pools.values():
                alloc_tokens += p.in_use * self.page_len
                live_tokens += sum(min(pos + 1, p.capacity)
                                   for pos in positions)
            frag = (1.0 - live_tokens / alloc_tokens if alloc_tokens
                    else 0.0)
        return {
            "page_len": self.page_len,
            "shards": self.shards,
            "pages_in_use": in_use,
            "pages_peak": sum(p.peak for p in self.pools.values()),
            "pages_total": total,
            "pools": {b: {"pages": p.pool_pages, "in_use": p.in_use,
                          "peak": p.peak, "page_slots": p.page_slots,
                          "ring": p.ring, "held": len(p.held),
                          "shard_pages": p.shard_pages}
                      for b, p in self.pools.items()},
            "reserved_kv_bytes": reserved,
            "contiguous_kv_bytes": contiguous,
            "reserved_reduction": (contiguous / reserved if reserved
                                   else 1.0),
            "fragmentation": frag,
        }
