"""Slot-based continuous-batching scheduler.

The decode batch has a fixed width (``num_slots``); requests are admitted
into freed slots *mid-flight* — there is no drain barrier, so the array
stays fed at full batch width under a stream of arrivals (the EIE
observation: compressed-weight inference pays off when the engine keeps
many concurrent requests in the array).

Admission is FIFO by (arrival, rid), which gives the no-starvation
property tested in tests/test_serve_engine.py: a request can only be
passed over by requests that arrived strictly earlier.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Tuple

from repro.serve.request import Request, RequestState


class SlotScheduler:
    def __init__(self, num_slots: int):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.free: deque = deque(range(num_slots))
        self.waiting: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.admitted_rids: List[int] = []   # admission order (for tests)

    # ------------------------------------------------------------ queue ----

    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def admit(self, now: float, fits=None) -> List[Tuple[int, Request]]:
        """Move due requests into free slots, FIFO by (arrival, rid).

        ``fits(req) -> bool`` is an optional capacity gate (the paged
        engine's out-of-pages check).  Admission stays strictly FIFO: a
        head-of-line request that doesn't fit *blocks* later requests
        rather than being skipped, preserving the no-starvation property
        — it waits in the queue until retirements free capacity.
        """
        admitted = []
        while self.free:
            due = [r for r in self.waiting if r.arrival <= now]
            if not due:
                break
            req = min(due, key=lambda r: (r.arrival, r.rid))
            if fits is not None and not fits(req):
                break
            self.waiting.remove(req)
            slot = self.free.popleft()
            self.active[slot] = req
            req.slot = slot
            req.state = RequestState.ACTIVE
            self.admitted_rids.append(req.rid)
            admitted.append((slot, req))
        return admitted

    def release(self, slot: int) -> None:
        req = self.active.pop(slot)
        req.state = RequestState.DONE
        self.free.append(slot)

    # ------------------------------------------------------------ views ----

    @property
    def has_work(self) -> bool:
        return bool(self.active) or bool(self.waiting)

    @property
    def num_active(self) -> int:
        return len(self.active)

    def next_arrival(self) -> float:
        assert self.waiting
        return min(r.arrival for r in self.waiting)
