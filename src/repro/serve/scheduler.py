"""Slot-based continuous-batching scheduler.

The decode batch has a fixed width (``num_slots``); requests are admitted
into freed slots *mid-flight* — there is no drain barrier, so the array
stays fed at full batch width under a stream of arrivals (the EIE
observation: compressed-weight inference pays off when the engine keeps
many concurrent requests in the array).

Admission is FIFO by (arrival, rid), which gives the no-starvation
property tested in tests/test_serve_engine.py: a request can only be
passed over by requests that arrived strictly earlier.  A *preempted*
request (``requeue``) keeps its original arrival, so it goes back to the
head of the line — the engine preempts youngest-first and re-admits
oldest-first, which is what makes recompute-on-preempt starvation-free.

Bookkeeping is bounded: the admission-order trace keeps only the last
``history`` rids (a deque), with a monotonic ``admitted_total`` counter —
a long-lived engine's memory does not grow with total traffic.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Tuple

from repro.serve.errors import AuditViolation
from repro.serve.request import Request, RequestState


class SlotScheduler:
    def __init__(self, num_slots: int, history: int = 4096):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.free: deque = deque(range(num_slots))
        self.waiting: List[Request] = []
        self.active: Dict[int, Request] = {}
        self._admitted_rids: deque = deque(maxlen=max(1, history))
        self.admitted_total = 0
        self.preemptions = 0

    # ------------------------------------------------------------ queue ----

    def submit(self, req: Request) -> None:
        req.transition(RequestState.WAITING)
        self.waiting.append(req)

    def admit(self, now: float, fits=None) -> List[Tuple[int, Request]]:
        """Move due requests into free slots, FIFO by (arrival, rid).

        ``fits(req) -> bool`` is an optional capacity gate (the paged
        engine's out-of-pages check).  Admission stays strictly FIFO: a
        head-of-line request that doesn't fit *blocks* later requests
        rather than being skipped, preserving the no-starvation property
        — it waits in the queue until retirements free capacity.
        """
        admitted = []
        while self.free:
            due = [r for r in self.waiting if r.arrival <= now]
            if not due:
                break
            req = min(due, key=lambda r: (r.arrival, r.rid))
            if fits is not None and not fits(req):
                break
            self.waiting.remove(req)
            slot = self.free.popleft()
            self.active[slot] = req
            req.slot = slot
            req.transition(RequestState.ACTIVE)
            self._admitted_rids.append(req.rid)
            self.admitted_total += 1
            admitted.append((slot, req))
        return admitted

    def release(self, slot: int,
                state: RequestState = RequestState.DONE) -> Request:
        """Free a slot into any terminal state (DONE by default; the
        engine passes CANCELLED / EXPIRED for aborted requests)."""
        req = self.active.pop(slot)
        req.transition(state)
        self.free.append(slot)
        return req

    def requeue(self, slot: int) -> Request:
        """Preempt: push the slot's request back onto the waiting queue
        (state WAITING, original arrival kept — it re-sorts to the head
        of the FIFO) and free the slot.  The engine re-ingests the
        request's generated prefix on re-admission."""
        req = self.active.pop(slot)
        req.transition(RequestState.WAITING)
        req.slot = None
        self.waiting.append(req)
        self.free.append(slot)
        self.preemptions += 1
        return req

    def cancel_waiting(self, req: Request) -> None:
        """Drop a queued request (client cancel / deadline expiry /
        shedding).  The caller applies the terminal transition."""
        self.waiting.remove(req)

    # ------------------------------------------------------------ views ----

    def register_metrics(self, reg) -> None:
        """Expose slot occupancy and admission counters as gauges."""
        reg.gauge("scheduler.waiting", lambda: len(self.waiting))
        reg.gauge("scheduler.active", lambda: len(self.active))
        reg.gauge("scheduler.free_slots", lambda: len(self.free))
        reg.gauge("scheduler.admitted_total",
                  lambda: self.admitted_total)
        reg.gauge("scheduler.preemptions", lambda: self.preemptions)

    @property
    def admitted_rids(self) -> List[int]:
        """Admission order, most recent ``history`` entries (for tests)."""
        return list(self._admitted_rids)

    @property
    def has_work(self) -> bool:
        return bool(self.active) or bool(self.waiting)

    @property
    def num_active(self) -> int:
        return len(self.active)

    def next_arrival(self) -> float:
        assert self.waiting
        return min(r.arrival for r in self.waiting)

    # ------------------------------------------------------------ audit ----

    def audit(self) -> None:
        """Slot-bookkeeping invariants (raises ``AuditViolation``):
        free and active slots partition [0, num_slots); no slot is freed
        twice; every active request agrees it owns its slot; every
        queued request is WAITING."""
        free = list(self.free)
        free_set, active_set = set(free), set(self.active)
        if len(free) != len(free_set):
            raise AuditViolation(f"duplicate free slot: {sorted(free)}")
        if free_set & active_set:
            raise AuditViolation(
                f"slot both free and active: {sorted(free_set & active_set)}")
        if free_set | active_set != set(range(self.num_slots)):
            raise AuditViolation(
                f"slots lost: free={sorted(free_set)} "
                f"active={sorted(active_set)} of {self.num_slots}")
        for slot, req in self.active.items():
            if req.state is not RequestState.ACTIVE or req.slot != slot:
                raise AuditViolation(
                    f"slot {slot}: rid {req.rid} state={req.state.value} "
                    f"claims slot {req.slot}")
        for req in self.waiting:
            if req.state is not RequestState.WAITING:
                raise AuditViolation(
                    f"queued rid {req.rid} in state {req.state.value}")
