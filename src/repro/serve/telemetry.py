"""Serving telemetry: one metrics registry, phase-timed step spans, and
a structured event log.

The paper's headline claims are *traffic* claims (EIM/SIDR cut SRAM
access 86 % vs SparTen), and EIE-style designs live or die on
per-component access counts made visible, not inferred — so the serving
engine's observability is a subsystem, not an afterthought.  Three
layers, all optional and all off by default:

* **Metrics registry** (`MetricsRegistry`): typed ``Counter`` /
  ``Gauge`` / ``Histogram`` metrics (histograms reuse the seeded
  ``RollingStat`` reservoir, so percentiles stay deterministic per
  trace) that every subsystem — engine, scheduler, paging, prefill
  planner, packed stream, faults/audit — registers into.  The engine's
  ``report()`` is a *rendered snapshot* of this one registry: named
  ``view`` entries reproduce the legacy section/field layout
  byte-for-byte (schema pinned by test), while the flat metrics export
  as a Prometheus text page (``to_prometheus()``) or a JSON snapshot
  (``--metrics-out``).

* **Step-phase spans** (`StepSpans`): monotonic-clock brackets around
  the host-side phases of ``ServeEngine.step`` — schedule /
  deadline-sweep / page-ensure / prefill / decode / host-sync / sample
  / audit — accumulated into per-phase histograms and, when
  ``--trace-out`` is set, emitted as Chrome trace-event JSON
  (perfetto-viewable) together with per-request lifecycle spans
  (QUEUED → PREFILL → DECODE) and instant markers for preemptions,
  faults and quarantines.  Spans bracket only host-side code; device
  time surfaces in the ``host_sync`` phase (the existing
  block-until-ready point), so enabling tracing adds no host
  transfers and no extra synchronization.

* **Event log** (`EventLog`): one JSONL schema unifying lifecycle
  transitions, fallback warnings, fault injections and audit
  violations — every record carries a monotonic timestamp, the engine
  step, a ``kind`` from ``EVENT_KINDS`` and (where applicable) the
  rid, so "what happened to request 1234" is one grep.

Telemetry-off is the default and is bit-identical and allocation-free
on the hot path: the engine holds ``spans is None`` / ``events is
None`` and every bracket is a plain ``is not None`` check — no span
objects, no context managers, no host transfers (asserted by test).

``Clock`` is the serving wall clock: started exactly once, *after*
warmup, through one idempotent ``start()`` — hoisted here from the two
``_t0`` resets the engine used to carry so compile time can never leak
into the first timed step again.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.serve.trace import RollingStat

__all__ = [
    "Clock", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "ChromeTrace", "StepSpans", "EventLog", "Telemetry", "PHASES",
    "EVENT_KINDS", "load_trace", "validate_trace", "validate_event",
    "validate_events",
]


# ---------------------------------------------------------------- clock ----

class Clock:
    """The serving wall clock: monotonic (``time.perf_counter``),
    started exactly once via the idempotent ``start()``.

    The engine calls ``start()`` *after* ``warmup()`` in both ``step``
    and ``run`` — one helper instead of the two hand-rolled ``_t0``
    resets it used to carry, so no call path can start the clock while
    XLA is still compiling (the warmup-leak regression test pins this).
    """

    def __init__(self) -> None:
        self._t0: Optional[float] = None

    @property
    def started(self) -> bool:
        return self._t0 is not None

    @property
    def t0(self) -> Optional[float]:
        return self._t0

    def start(self) -> None:
        """Start the clock; later calls are no-ops."""
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def now(self) -> float:
        """Seconds since ``start()``; raises if never started."""
        assert self._t0 is not None, "Clock.now() before start()"
        return time.perf_counter() - self._t0

    def now_or_zero(self) -> float:
        """``now()``, or 0.0 before the clock starts (pre-run events)."""
        return time.perf_counter() - self._t0 if self._t0 is not None \
            else 0.0

    def rel(self, t_abs: float) -> float:
        """Convert an absolute ``perf_counter`` stamp to clock time."""
        assert self._t0 is not None
        return t_abs - self._t0


# -------------------------------------------------------------- metrics ----

class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        assert n >= 0, f"counter {self.name} decremented by {n}"
        self.value += n


class Gauge:
    """Point-in-time value: either set explicitly (``set``) or computed
    at snapshot time from a callback (``fn``) — subsystems register
    callback gauges over their live state so the registry never holds a
    stale copy.  Values may be non-numeric (fallback-reason strings,
    None); those appear in the JSON snapshot and are skipped by the
    Prometheus exporter."""

    kind = "gauge"

    def __init__(self, name: str, fn: Optional[Callable] = None,
                 help: str = ""):
        self.name = name
        self.help = help
        self._fn = fn
        self._value = None

    def set(self, v) -> None:
        assert self._fn is None, f"gauge {self.name} is callback-backed"
        self._value = v

    @property
    def value(self):
        return self._fn() if self._fn is not None else self._value


class Histogram:
    """Distribution metric over the seeded ``RollingStat`` reservoir:
    exact count/sum/mean, deterministic p50/p99 (exact below the
    reservoir cap — identical to a full scan on short traces)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", cap: int = 2048,
                 seed: int = 0):
        self.name = name
        self.help = help
        self.stat = RollingStat(cap=cap, seed=seed)

    def observe(self, v) -> None:
        self.stat.add(v)

    @property
    def count(self) -> int:
        return self.stat.count

    @property
    def sum(self) -> float:
        return self.stat.total

    @property
    def mean(self) -> float:
        return self.stat.mean

    def percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        return self.stat.percentiles(qs)


def _nan_to_none(v):
    return None if isinstance(v, float) and math.isnan(v) else v


def prom_name(name: str, prefix: str = "repro_serve_") -> str:
    """Sanitize a dotted metric name into Prometheus form."""
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return prefix + out


class MetricsRegistry:
    """The one place metrics live.

    Two faces:

    * flat typed metrics (``counter`` / ``gauge`` / ``histogram``),
      snapshot-exported as JSON (``snapshot``/``write``) or a
      Prometheus text page (``to_prometheus``);
    * named ``view`` entries — callables evaluated at render time —
      whose insertion-ordered evaluation *is* the engine's
      ``report()`` dict, so the legacy report schema is a rendered
      projection of the registry rather than a second bookkeeping
      system.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}
        self._views: Dict[str, Callable[[], object]] = {}

    # ---- registration -------------------------------------------------

    def _add(self, metric):
        assert metric.name not in self._metrics, \
            f"duplicate metric {metric.name}"
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._add(Counter(name, help))

    def gauge(self, name: str, fn: Optional[Callable] = None,
              help: str = "") -> Gauge:
        return self._add(Gauge(name, fn, help))

    def histogram(self, name: str, help: str = "", cap: int = 2048,
                  seed: int = 0) -> Histogram:
        return self._add(Histogram(name, help, cap=cap, seed=seed))

    def view(self, name: str, fn: Callable[[], object]) -> None:
        """Register a top-level ``report()`` entry (scalar or section)."""
        assert name not in self._views, f"duplicate view {name}"
        self._views[name] = fn

    def get(self, name: str):
        return self._metrics[name]

    @property
    def names(self) -> List[str]:
        return list(self._metrics)

    # ---- rendering ----------------------------------------------------

    def render(self) -> Dict:
        """Evaluate every view in registration order — the report."""
        return {name: fn() for name, fn in self._views.items()}

    def snapshot(self) -> Dict:
        """Flat ``{name: value}`` snapshot of every metric.  Histograms
        render as ``{count, sum, mean, p50, p99}``; NaN (empty
        histogram) becomes None so the snapshot is strict JSON."""
        out: Dict[str, object] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                pct = m.percentiles()
                out[name] = {
                    "count": m.count, "sum": m.sum,
                    "mean": _nan_to_none(m.mean),
                    "p50": _nan_to_none(pct["p50"]),
                    "p99": _nan_to_none(pct["p99"]),
                }
            else:
                out[name] = _nan_to_none(m.value)
        return out

    def to_prometheus(self, prefix: str = "repro_serve_") -> str:
        """Prometheus text exposition (0.0.4).  Counters and numeric
        gauges export directly; histograms export as summaries
        (quantile-labelled samples + ``_sum``/``_count``); non-numeric
        gauges (reason strings, None) are skipped — they live in the
        JSON snapshot and the rendered report."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            pname = prom_name(name, prefix)
            if isinstance(m, Histogram):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} summary")
                pct = m.percentiles()
                for q, key in ((0.5, "p50"), (0.99, "p99")):
                    v = pct[key]
                    if not math.isnan(v):
                        lines.append(f'{pname}{{quantile="{q}"}} {v}')
                lines.append(f"{pname}_sum {m.sum}")
                lines.append(f"{pname}_count {m.count}")
                continue
            v = m.value
            if isinstance(v, bool):
                v = int(v)
            if not isinstance(v, (int, float)):
                continue
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            lines.append(f"# TYPE {pname} {m.kind}")
            lines.append(f"{pname} {v}")
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        """Write the snapshot: Prometheus text for ``.prom`` paths,
        strict JSON otherwise."""
        if path.endswith(".prom"):
            with open(path, "w") as f:
                f.write(self.to_prometheus())
            return
        with open(path, "w") as f:
            json.dump({"schema": "repro.serve.metrics/v1",
                       "metrics": self.snapshot()}, f, indent=2,
                      allow_nan=False)


# --------------------------------------------------------- chrome trace ----

#: pid/tid layout of the exported trace: engine step + phase spans on
#: one track, each request's lifecycle on its own thread of a second
#: process (perfetto renders them as one row per rid).
PID_ENGINE, TID_STEP = 1, 0
PID_REQUESTS = 2


class ChromeTrace:
    """Chrome trace-event JSON accumulator (perfetto / chrome://tracing
    loadable).  Timestamps are serving-clock seconds converted to the
    format's microseconds; events buffer in memory and ``write()`` dumps
    the standard ``{"traceEvents": [...]}`` envelope."""

    def __init__(self) -> None:
        self.events: List[Dict] = []
        self._named_threads: set = set()
        self._meta(PID_ENGINE, None, "serve_engine")
        self._meta(PID_ENGINE, TID_STEP, "step")
        self._meta(PID_REQUESTS, None, "requests")

    def _meta(self, pid: int, tid: Optional[int], name: str) -> None:
        if tid is None:
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0,
                                "args": {"name": name}})
        else:
            self.events.append({"ph": "M", "name": "thread_name",
                                "pid": pid, "tid": tid,
                                "args": {"name": name}})

    def ensure_thread(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) not in self._named_threads:
            self._named_threads.add((pid, tid))
            self._meta(pid, tid, name)

    def complete(self, name: str, t0_s: float, dur_s: float, *,
                 pid: int = PID_ENGINE, tid: int = TID_STEP,
                 cat: str = "phase",
                 args: Optional[Dict] = None) -> None:
        ev = {"ph": "X", "name": name, "cat": cat,
              "ts": t0_s * 1e6, "dur": max(dur_s, 0.0) * 1e6,
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, t_s: float, values: Dict[str, float], *,
                pid: int = PID_ENGINE, tid: int = TID_STEP,
                cat: str = "traffic") -> None:
        """One counter-track sample (ph "C"): perfetto renders each
        ``values`` key as a stacked series under ``name`` — the per-phase
        HBM byte tracks."""
        self.events.append({"ph": "C", "name": name, "cat": cat,
                            "ts": t_s * 1e6, "pid": pid, "tid": tid,
                            "args": dict(values)})

    def instant(self, name: str, t_s: float, *, pid: int = PID_ENGINE,
                tid: int = TID_STEP, cat: str = "marker",
                args: Optional[Dict] = None) -> None:
        ev = {"ph": "i", "name": name, "cat": cat, "ts": t_s * 1e6,
              "s": "t", "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events,
                       "displayTimeUnit": "ms"}, f)


def load_trace(path: str) -> List[Dict]:
    with open(path) as f:
        data = json.load(f)
    assert isinstance(data, dict) and "traceEvents" in data, \
        f"{path}: not a Chrome trace-event file"
    return data["traceEvents"]


def validate_trace(events_or_path) -> Dict:
    """Structural validation of an exported trace (the CI smoke's
    contract):

    * every phase span nests inside exactly one step span (no phase
      leaks across a step boundary), and phases within a step do not
      overlap one another;
    * per step, the phase durations sum to at most the step duration,
      and the coverage fraction is reported (the smoke asserts >= 95 %:
      the phase taxonomy accounts for where step wall time goes);
    * request spans (QUEUED/PREFILL/DECODE per tid) appear in lifecycle
      order.

    Returns summary stats; raises ``ValueError`` on violation.
    """
    events = (load_trace(events_or_path)
              if isinstance(events_or_path, str) else events_or_path)
    eps = 5.0  # us of float slack on span edges
    steps = sorted((e for e in events
                    if e.get("ph") == "X" and e.get("cat") == "step"),
                   key=lambda e: e["ts"])
    phases = [e for e in events
              if e.get("ph") == "X" and e.get("cat") == "phase"]
    by_step: Dict[int, List[Dict]] = {i: [] for i in range(len(steps))}
    for p in phases:
        host = None
        for i, s in enumerate(steps):
            if (s["ts"] - eps <= p["ts"]
                    and p["ts"] + p["dur"] <= s["ts"] + s["dur"] + eps):
                host = i
                break
        if host is None:
            raise ValueError(
                f"phase span {p['name']} at ts={p['ts']:.1f}us nests in "
                f"no step span")
        by_step[host].append(p)
    coverage = []
    phase_us = step_us = 0.0
    for i, s in enumerate(steps):
        ph = sorted(by_step[i], key=lambda e: e["ts"])
        for a, b in zip(ph, ph[1:]):
            if a["ts"] + a["dur"] > b["ts"] + eps:
                raise ValueError(
                    f"phases {a['name']} and {b['name']} overlap inside "
                    f"step {i}")
        total = sum(p["dur"] for p in ph)
        if s["dur"] > 0:
            if total > s["dur"] + eps * max(1, len(ph)):
                raise ValueError(
                    f"step {i}: phase durations sum past the step wall "
                    f"({total:.1f}us > {s['dur']:.1f}us)")
            coverage.append(total / s["dur"])
            phase_us += total
            step_us += s["dur"]
    order = {"QUEUED": 0, "PREFILL": 1, "DECODE": 2}
    req_spans: Dict[int, List[Dict]] = {}
    for e in events:
        if e.get("ph") == "X" and e.get("cat") == "request":
            req_spans.setdefault(e["tid"], []).append(e)
    for tid, spans in req_spans.items():
        spans.sort(key=lambda e: (e["ts"], order.get(e["name"], 9)))
        ranks = [order.get(e["name"], -1) for e in spans]
        if -1 in ranks or ranks != sorted(ranks):
            raise ValueError(
                f"request tid={tid}: lifecycle spans out of order: "
                f"{[e['name'] for e in spans]}")
    return {
        "steps": len(steps),
        "phase_spans": len(phases),
        "requests": len(req_spans),
        "min_coverage": min(coverage) if coverage else None,
        "mean_coverage": (sum(coverage) / len(coverage)
                          if coverage else None),
        # duration-weighted: a scheduler hiccup between brackets in one
        # microsecond-scale step can crater min_coverage without the
        # taxonomy actually leaking time — this is the 5 % criterion
        "agg_coverage": phase_us / step_us if step_us else None,
    }


# ----------------------------------------------------------- step spans ----

#: The step-phase taxonomy (DESIGN_SERVING.md §Observability).  Phases
#: are sequential and non-overlapping inside one step; together they
#: cover (nearly) the whole host-side step wall, so their histograms
#: answer "where does a step's time go".
PHASES = ("schedule", "prefill", "page_ensure", "decode", "host_sync",
          "sample", "deadline_sweep", "audit")

_PHASE_SEEDS = {p: 0x7e1e + i for i, p in enumerate(PHASES)}


class StepSpans:
    """Phase-timed spans around ``ServeEngine.step``'s host-side code.

    ``begin(name)`` / ``end()`` bracket one phase at a time (phases
    never nest — the step span is the only parent); each bracket costs
    two ``perf_counter`` reads and one histogram observe.  With a
    ``ChromeTrace`` attached, every phase and step also emits a
    complete event on the engine track.
    """

    def __init__(self, registry: MetricsRegistry, clock: Clock,
                 trace: Optional[ChromeTrace] = None):
        self.clock = clock
        self.trace = trace
        self.h_phase = {
            p: registry.histogram(
                f"step.phase.{p}_s", seed=_PHASE_SEEDS[p],
                help=f"host-side seconds in the {p} phase per step")
            for p in PHASES}
        self.h_step = registry.histogram(
            "step.wall_s", seed=0x57e9,
            help="host-side wall seconds per engine step")
        self.h_coverage = registry.histogram(
            "step.phase_coverage", seed=0xc04e,
            help="fraction of the step wall covered by phase spans")
        self.steps = 0
        self._t_step: Optional[float] = None
        self._step_idx = 0
        self._acc = 0.0
        self._t_phase: Optional[float] = None
        self._phase: Optional[str] = None

    def step_begin(self, step: int, t_abs: Optional[float] = None) -> None:
        self._t_step = time.perf_counter() if t_abs is None else t_abs
        self._step_idx = step
        self._acc = 0.0

    def begin(self, name: str) -> None:
        assert self._phase is None, \
            f"phase {name} opened inside {self._phase}"
        self._phase = name
        self._t_phase = time.perf_counter()

    def end(self) -> None:
        t1 = time.perf_counter()
        name, t0 = self._phase, self._t_phase
        assert name is not None, "StepSpans.end() with no open phase"
        self._phase = None
        dt = t1 - t0
        self._acc += dt
        self.h_phase[name].observe(dt)
        if self.trace is not None:
            self.trace.complete(name, self.clock.rel(t0), dt,
                                cat="phase")

    def step_end(self) -> None:
        assert self._phase is None, \
            f"step ended with phase {self._phase} still open"
        t1 = time.perf_counter()
        dur = t1 - self._t_step
        self.h_step.observe(dur)
        self.h_coverage.observe(self._acc / dur if dur > 0 else 1.0)
        self.steps += 1
        if self.trace is not None:
            self.trace.complete("step", self.clock.rel(self._t_step),
                                dur, cat="step",
                                args={"step": self._step_idx})


# ------------------------------------------------------------ event log ----

#: The unified event schema's ``kind`` vocabulary: request lifecycle
#: transitions, the fallback/fault/quarantine surface, and audit
#: violations — one stream, one set of field names.
EVENT_KINDS = frozenset({
    "submit", "admit", "prefill_done", "first_token", "preempt",
    "done", "cancelled", "expired", "shed", "fallback", "fault",
    "quarantine", "audit_violation",
})

_REQUIRED = ("t", "step", "kind")


def validate_event(rec: Dict) -> None:
    """Raise ``ValueError`` unless ``rec`` matches the event schema:
    ``t`` (float seconds, monotonic per log), ``step`` (int >= 0),
    ``kind`` (one of ``EVENT_KINDS``), ``rid`` (int or None), and
    JSON-scalar extras."""
    for key in _REQUIRED:
        if key not in rec:
            raise ValueError(f"event missing required field {key!r}: "
                             f"{rec}")
    if not isinstance(rec["t"], (int, float)) or rec["t"] < 0:
        raise ValueError(f"event t must be a non-negative number: {rec}")
    if not isinstance(rec["step"], int) or rec["step"] < 0:
        raise ValueError(f"event step must be a non-negative int: {rec}")
    if rec["kind"] not in EVENT_KINDS:
        raise ValueError(f"unknown event kind {rec['kind']!r}: {rec}")
    rid = rec.get("rid")
    if rid is not None and not isinstance(rid, int):
        raise ValueError(f"event rid must be int or None: {rec}")


def validate_events(path: str) -> int:
    """Validate a JSONL event file: every line parses, matches the
    schema, and timestamps are monotonic.  Returns the record count."""
    last_t = -1.0
    n = 0
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}")
            validate_event(rec)
            if rec["t"] < last_t:
                raise ValueError(
                    f"{path}:{i + 1}: timestamp went backwards "
                    f"({rec['t']} < {last_t})")
            last_t = rec["t"]
            n += 1
    return n


class EventLog:
    """Structured JSONL event log.  Records buffer in memory (bounded
    by ``cap``: the oldest records drop first, with a counter so the
    truncation is visible) and ``write()`` dumps one JSON object per
    line."""

    def __init__(self, cap: int = 65536):
        from collections import deque
        self.records = deque(maxlen=cap)
        self.emitted = 0
        self.dropped = 0

    def emit(self, kind: str, *, t: float, step: int,
             rid: Optional[int] = None, **fields) -> None:
        assert kind in EVENT_KINDS, f"unknown event kind {kind!r}"
        rec = {"t": t, "step": step, "kind": kind, "rid": rid}
        if fields:
            rec.update(fields)
        if len(self.records) == self.records.maxlen:
            self.dropped += 1
        self.records.append(rec)
        self.emitted += 1

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec, allow_nan=False) + "\n")


# ------------------------------------------------------------ telemetry ----

class Telemetry:
    """The engine's telemetry bundle: step spans (always, when
    telemetry is on — they feed the registry's phase histograms), a
    Chrome trace accumulator when ``trace_out`` is set, and an event
    log when ``events_out`` is set.  ``close()`` writes every
    configured artifact (idempotent)."""

    def __init__(self, registry: MetricsRegistry, clock: Clock, *,
                 trace_out: Optional[str] = None,
                 events_out: Optional[str] = None,
                 metrics_out: Optional[str] = None):
        self.registry = registry
        self.clock = clock
        self.trace_out = trace_out
        self.events_out = events_out
        self.metrics_out = metrics_out
        self.trace = ChromeTrace() if trace_out else None
        self.events = EventLog() if events_out else None
        self.spans = StepSpans(registry, clock, trace=self.trace)
        self._closed = False

    def request_done(self, req) -> None:
        """Emit a retired/aborted request's lifecycle spans + instant
        markers onto its own trace thread (one perfetto row per rid)."""
        if self.trace is None:
            return
        spans, instants = req.timeline()
        if not spans and not instants:
            return
        tid = req.rid
        self.trace.ensure_thread(PID_REQUESTS, tid, f"rid {req.rid}")
        args = {"rid": req.rid, "state": req.state.name,
                "tokens": len(req.tokens)}
        if req.first_token_s is not None:
            args["first_token_ms"] = round(req.first_token_s * 1e3, 3)
        for name, t0, t1 in spans:
            self.trace.complete(name, t0, t1 - t0, pid=PID_REQUESTS,
                                tid=tid, cat="request", args=args)
        for name, t in instants:
            self.trace.instant(name, t, pid=PID_REQUESTS, tid=tid,
                               cat="request", args={"rid": req.rid})

    def close(self) -> List[str]:
        """Write every configured artifact; returns the paths written.
        Safe to call more than once (later calls are no-ops)."""
        if self._closed:
            return []
        self._closed = True
        written = []
        for path, fn in (
                (self.trace_out,
                 lambda p: self.trace.write(p)),
                (self.events_out,
                 lambda p: self.events.write(p)),
                (self.metrics_out,
                 lambda p: self.registry.write(p))):
            if path:
                d = os.path.dirname(path)
                if d:
                    os.makedirs(d, exist_ok=True)
                fn(path)
                written.append(path)
        return written
