"""Whole-stack packed-model subsystem: prune once, pack once, stream
bitmap-compressed on every decode step.

``pack_model`` walks the params tree (the ``param_shapes`` inventory,
stacked over periods) and packs every serve-time GEMM operand into one
``BitmapWeight`` per tensor, choosing the largest valid ``(BK, BN)``
tile per shape:

* **period-stacked 2-D projections** (``pack_bitmap_stacked``):
  attention ``wq/wk/wv/wo``, MLP ``w_gate/w_up/w_down``, the MoE
  ``router``, mamba ``in/x/dt/out`` projections, rwkv
  ``w_r/w_k/w_v/w_g/w_o``, ``decay_A/decay_B``, ``mix_A`` and the
  rwkv channel-mix ``cm_k/cm_v/cm_r``;
* **group-stacked expert tensors** (``pack_bitmap_experts``): MoE
  ``w_gate/w_up/w_down`` — a ``(P, E, D, F)`` stack whose per-expert
  slices dispatch through ``kernels/ops.bitmap_spmm_grouped`` — and
  rwkv's 5-way lerp stack ``mix_B``, which shares the layout.

The result is a pytree mirroring ``params["blocks"]`` (``BitmapWeight``
leaves where packed, ``None`` where dense) that threads through
``build_serve_step`` → ``decode_step`` → ``decode_hidden`` (and the
chunked-prefill path ``build_prefill_step`` → ``prefill_hidden``) into
``layers.matmul_or_bitmap`` / ``layers.expert_matmul_or_bitmap`` and the
ssm decode cells, so the per-step matmuls dispatch via
``kernels/ops.bitmap_spmm`` / ``bitmap_spmm_grouped`` instead of dense
``@``.

Invariants (DESIGN_PACKED.md has the full subsystem doc):

* **Packing is lossless** — the per-tensor value-slot budget equals the
  max tile non-zero count, so the packed stream is numerically identical
  to dense dispatch of the same (pruned) weights; compression comes only
  from upstream pruning.
* **Every fallback carries a reason** — a tensor that cannot pack is
  served dense with the reason recorded in the manifest (no valid tile,
  unexpected rank, not a GEMM operand, …); nothing silently degrades.
* **Modeled bytes are the compressed stream the kernel actually
  fetches** — a pack-time ``dense_cache`` (the xla-oracle rendering)
  never counts toward ``hbm_bytes``.
* **Router-gated expert stacks account per *activated* expert** — a
  gather-dispatch serving engine streams only the experts the router
  selected, so ``stream_report(activated_experts=...)`` scales those
  entries by ``min(E, activated) / E`` (the engine passes
  ``num_slots × top_k``, the per-step worst case) whether the stack
  packed or fell back; always-active group stacks (rwkv ``mix_B``) and
  everything else count in full.  Note the repo's capacity-dispatch
  reference *executes* all stored experts (like the xla oracle, it
  models the accelerator's dataflow rather than reproducing it) —
  DESIGN_PACKED.md §6 spells out modeled vs executed.

This is the paper's regime end-to-end: EIE runs *every* FC layer from
compressed storage; here the entire decode stack — MoE expert stacks
and SSM mixers included — streams the bitmap format.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sparse.format import (BitmapWeight, pack_bitmap_experts,
                                 pack_bitmap_stacked, shard_bitmap)

# (component, tensor) pairs with a compressed dispatch path in the
# decode step.  2-D entries are period-stacked projections; GROUPED
# entries are (P, G, K, N) stacks dispatched per group.  Everything else
# records a fallback reason in the manifest.
DISPATCHABLE_2D = {
    ("attn", "wq"), ("attn", "wk"), ("attn", "wv"), ("attn", "wo"),
    ("mlp", "w_gate"), ("mlp", "w_up"), ("mlp", "w_down"),
    ("moe", "router"),
    ("mamba", "in_proj"), ("mamba", "x_proj"), ("mamba", "dt_proj"),
    ("mamba", "out_proj"),
    ("rwkv", "w_r"), ("rwkv", "w_k"), ("rwkv", "w_v"), ("rwkv", "w_g"),
    ("rwkv", "w_o"), ("rwkv", "decay_A"), ("rwkv", "decay_B"),
    ("rwkv", "mix_A"),
    ("rwkv_cm", "cm_k"), ("rwkv_cm", "cm_v"), ("rwkv_cm", "cm_r"),
}
DISPATCHABLE_GROUPED = {
    ("moe", "w_gate"), ("moe", "w_up"), ("moe", "w_down"),
    ("rwkv", "mix_B"),
}
# router-gated expert stacks: per-step traffic scales with *activated*
# experts (rwkv's mix_B is group-stacked but always fully active)
ROUTED_EXPERT = {("moe", "w_gate"), ("moe", "w_up"), ("moe", "w_down")}


def activated_scale(experts: int, activated: Optional[int]) -> float:
    """The accounting rule, single-sourced: router-gated expert stacks
    stream ``min(E, activated)`` of their ``E`` stored experts per step
    (``experts == 0`` or ``activated is None`` ⇒ no scaling)."""
    if not experts or activated is None:
        return 1.0
    return min(experts, activated) / experts


def choose_block(k: int, n: int, cap: int = 128
                 ) -> Optional[Tuple[int, int]]:
    """Largest (BK, BN) bitmap tile dividing (k, n); BN % 8 == 0."""
    bk = next((d for d in range(min(k, cap), 0, -1) if k % d == 0), None)
    bn = next((d for d in range(min(n, cap), 0, -1)
               if n % d == 0 and d % 8 == 0), None)
    if bk is None or bn is None:
        return None
    return bk, bn


@dataclasses.dataclass
class PackEntry:
    """Manifest row: one tensor's pack decision + modeled per-step bytes.

    ``sparse_bytes``/``dense_bytes`` are *stored-stack* totals (all
    periods, all experts); the per-activated-expert scaling happens in
    ``PackedModel.stream_report``.  ``layout`` is ``"stacked"``
    (period-stacked 2-D), ``"grouped"`` (expert/group stack) or
    ``"dense"`` (fallback).  ``experts`` is the stored expert count for
    router-gated stacks (0 otherwise).
    """

    path: str
    shape: Tuple[int, ...]
    packed: bool
    reason: str                      # "" when packed, else why dense
    block: Optional[Tuple[int, int]]
    sparsity: float                  # measured zero fraction
    sparse_bytes: int                # streamed per step on the chosen path
    dense_bytes: int
    layout: str = "dense"
    experts: int = 0
    #: sharded layout: ("col"|"row", S) when the packed leaf carries an
    #: explicit shard axis; None for replicated/unsharded tensors
    shard: Optional[Tuple[str, int]] = None
    #: why a TP-ruled tensor could not shard (stored replicated); ""
    #: when sharded or when no rule applies
    shard_reason: str = ""


@dataclasses.dataclass
class PackedModel:
    """The packed pytree + its manifest and aggregate traffic model."""

    blocks: Dict                     # mirrors params["blocks"]
    manifest: List[PackEntry]
    shards: int = 1                  # model-axis shard count at pack time

    @property
    def packed_entries(self) -> List[PackEntry]:
        return [e for e in self.manifest if e.packed]

    @property
    def fallback_entries(self) -> List[PackEntry]:
        return [e for e in self.manifest if not e.packed]

    def leaves(self) -> List[Tuple[str, BitmapWeight]]:
        """Every currently-packed ``(path, BitmapWeight)`` leaf, manifest
        order — the fault injector's target list and the integrity
        auditor's checksum domain."""
        out = []
        for bname, bdict in self.blocks.items():
            for comp, tensors in bdict.items():
                for name, bw in tensors.items():
                    if bw is not None:
                        out.append((f"blocks/{bname}/{comp}/{name}", bw))
        return out

    def replace_leaf(self, path: str, bw: Optional[BitmapWeight]) -> None:
        """Swap the leaf at ``path`` (fault injection writes a corrupted
        copy; quarantine writes ``None``)."""
        _, bname, comp, name = path.split("/")
        assert name in self.blocks[bname][comp], path
        self.blocks[bname][comp][name] = bw

    def quarantine(self, path: str, reason: str) -> bool:
        """Serve ``path`` dense from now on: the leaf becomes ``None``
        (``matmul_or_bitmap`` dispatches the dense params tensor) and
        the manifest entry flips to a recorded fallback carrying
        ``reason``, so ``stream_report()`` and the fallback snapshot
        reflect the quarantine.  Returns False if already dense."""
        _, bname, comp, name = path.split("/")
        if self.blocks.get(bname, {}).get(comp, {}).get(name) is None:
            return False
        self.blocks[bname][comp][name] = None
        for e in self.manifest:
            if e.path == path:
                e.packed = False
                e.reason = reason
                e.layout = "dense"
                e.block = None
                e.sparse_bytes = e.dense_bytes
                e.shard = None
        return True

    def register_metrics(self, reg) -> None:
        reg.gauge("stream.packed_tensors",
                  lambda: len(self.packed_entries))
        reg.gauge("stream.fallback_tensors",
                  lambda: len(self.fallback_entries))

    def stream_report(self, activated_experts: Optional[int] = None) -> Dict:
        """Modeled per-step weight-HBM bytes across the stack (no head —
        the engine adds its head term on top).

        ``activated_experts`` (the engine passes ``num_slots × top_k``):
        router-gated expert stacks stream only the experts the router
        selected, so their stored-stack bytes scale by
        ``min(E, activated) / E`` — on the sparse *and* the dense side,
        since a gather-dispatch dense baseline also fetches only
        activated experts; the reduction therefore isolates the format,
        not the gating (accounting rule in DESIGN_PACKED.md).
        """
        def step_bytes(e: PackEntry, attr: str) -> int:
            return int(round(getattr(e, attr)
                             * activated_scale(e.experts,
                                               activated_experts)))

        sparse = sum(step_bytes(e, "sparse_bytes") for e in self.manifest)
        dense = sum(step_bytes(e, "dense_bytes") for e in self.manifest)
        dev_sparse = sum(
            entry_device_bytes(e, "sparse_bytes", activated_experts)
            for e in self.manifest)
        dev_dense = sum(
            entry_device_bytes(e, "dense_bytes", activated_experts)
            for e in self.manifest)
        return {
            "sparse_bytes_per_step": sparse,
            "dense_bytes_per_step": dense,
            "reduction": dense / sparse if sparse else 1.0,
            "packed_tensors": len(self.packed_entries),
            "fallback_tensors": len(self.fallback_entries),
            "activated_experts": activated_experts,
            "fallbacks": {e.path: e.reason for e in self.fallback_entries},
            "shards": self.shards,
            "device_sparse_bytes_per_step": dev_sparse,
            "device_dense_bytes_per_step": dev_dense,
            "shard_fallbacks": {e.path: e.shard_reason
                                for e in self.manifest if e.shard_reason},
        }


def entry_device_bytes(e: PackEntry, attr: str,
                       activated: Optional[int]) -> int:
    """Per-device per-step bytes for one manifest row: the exact
    aggregate accounting (``int(round(bytes × activated_scale))``)
    divided by the tensor's shard count — single-sourced so the traffic
    ledger's per-device rows sum to the engine's device aggregates by
    construction."""
    b = int(round(getattr(e, attr) * activated_scale(e.experts, activated)))
    return b // e.shard[1] if e.shard is not None else b


def _shard_block(comp: str, name: str, k: int, n: int, cap: int,
                 shards: int) -> Tuple[Optional[Tuple[int, int]],
                                       Optional[Tuple[str, int]], str]:
    """Choose the (block, shard, shard_reason) for one tensor.

    With ``shards == 1`` or no TP rule for (comp, name), this is plain
    ``choose_block`` with no shard.  Otherwise the tile is chosen against
    the *per-shard* slice — ``(k, n/S)`` column-parallel, ``(k/S, n)``
    row-parallel — so every shard's range is whole tiles; a dim the
    shard count doesn't divide (or with no valid per-shard tile) stays
    replicated with a typed reason instead of failing the pack.
    """
    from repro.launch.sharding import packed_mode
    mode = shards > 1 and packed_mode(comp, name) or None
    if not mode:
        return choose_block(k, n, cap), None, ""
    dim, dim_name = (n, "N") if mode == "col" else (k, "K")
    if dim % shards != 0:
        return choose_block(k, n, cap), None, (
            f"shard: {dim_name}={dim} not divisible by {shards} shards; "
            f"stored replicated")
    block = (choose_block(k, n // shards, cap) if mode == "col"
             else choose_block(k // shards, n, cap))
    if block is None:
        return choose_block(k, n, cap), None, (
            f"shard: no (BK, BN) tile fits the per-shard "
            f"{'column' if mode == 'col' else 'row'} slice; "
            f"stored replicated")
    return block, (mode, shards), ""


def _pack_leaf(path: str, comp: str, name: str, w, cap: int,
               cache_dense: bool, shards: int = 1
               ) -> Tuple[PackEntry, Optional[BitmapWeight]]:
    arr = np.asarray(w)
    dense_bytes = arr.size * arr.dtype.itemsize
    sparsity = 1.0 - np.count_nonzero(arr) / max(arr.size, 1)
    key = (comp, name)
    # the activated-expert accounting applies to router-gated stacks
    # whether they pack or fall back — a gather-dispatch dense baseline
    # also fetches only the selected experts
    routed = (arr.shape[1] if key in ROUTED_EXPERT and arr.ndim == 4
              else 0)

    def fallback(reason: str) -> Tuple[PackEntry, None]:
        return PackEntry(path=path, shape=arr.shape, packed=False,
                         reason=reason, block=None, sparsity=sparsity,
                         sparse_bytes=dense_bytes,
                         dense_bytes=dense_bytes, experts=routed), None
    if key in DISPATCHABLE_GROUPED:
        if arr.ndim != 4:            # (P, G, K, N) = period × group stack
            return fallback(f"group stack with unexpected rank "
                            f"(ndim={arr.ndim}, want 4)")
        _, g, k, n = arr.shape
        block, shard, shard_reason = _shard_block(comp, name, k, n, cap,
                                                  shards)
        if block is None:
            return fallback(
                f"no (BK, BN) tile divides ({k}, {n}) with BN % 8")
        bw = pack_bitmap_experts(arr, block=block, cache_dense=cache_dense)
        if shard is not None:
            bw = shard_bitmap(bw, shard[1], shard[0])
        entry = PackEntry(path=path, shape=arr.shape, packed=True, reason="",
                          block=block, sparsity=sparsity,
                          sparse_bytes=bw.hbm_bytes, dense_bytes=dense_bytes,
                          layout="grouped", experts=routed, shard=shard,
                          shard_reason=shard_reason)
        return entry, bw
    if key not in DISPATCHABLE_2D:
        # every GEMM operand of the decode step is listed above; the rest
        # are elementwise/state/conv tensors with no matmul to compress
        return fallback("not a GEMM operand (elementwise/state/conv tensor)")
    if arr.ndim != 3:                # (P, K, N) = period-stacked projection
        return fallback(f"not a 2-D projection (ndim={arr.ndim - 1})")
    _, k, n = arr.shape
    block, shard, shard_reason = _shard_block(comp, name, k, n, cap, shards)
    if block is None:
        return fallback(f"no (BK, BN) tile divides ({k}, {n}) with BN % 8")
    bw = pack_bitmap_stacked(arr, block=block, cache_dense=cache_dense)
    if shard is not None:
        bw = shard_bitmap(bw, shard[1], shard[0])
    entry = PackEntry(path=path, shape=arr.shape, packed=True, reason="",
                      block=block, sparsity=sparsity,
                      sparse_bytes=bw.hbm_bytes, dense_bytes=dense_bytes,
                      layout="stacked", shard=shard,
                      shard_reason=shard_reason)
    return entry, bw


def pack_model(params: Dict, cap: int = 128,
               cache_dense: bool = False, shards: int = 1) -> PackedModel:
    """Pack every dispatchable serve-time GEMM operand of ``params``.

    Packing is lossless (per-tensor budget = max tile non-zero count), so
    the packed stream is numerically identical to dense dispatch — the
    compression comes from whatever pruning already happened upstream
    (``global_l1_prune`` in the engine).

    ``cache_dense`` attaches a pack-time dense rendering per tensor for
    the xla oracle dispatch (decompression is a pack-time cost off-TPU;
    it never counts toward the modeled HBM bytes) — the engine enables
    it when the resolved kernel impl is "xla".

    ``shards > 1`` packs every TP-ruled tensor (``launch.sharding``'s
    PACKED_COL/PACKED_ROW) with an explicit shard axis so each
    model-axis device owns a local bitmap+values slice; tensors whose
    sharded dim the count doesn't divide stay replicated with a typed
    ``shard_reason`` in the manifest.
    """
    manifest: List[PackEntry] = []
    packed_blocks: Dict = {}
    for bname, bdict in params["blocks"].items():
        packed_b: Dict = {}
        for comp, tensors in bdict.items():
            packed_c: Dict = {}
            for name, w in tensors.items():
                path = f"blocks/{bname}/{comp}/{name}"
                entry, bw = _pack_leaf(path, comp, name, w, cap, cache_dense,
                                       shards)
                manifest.append(entry)
                packed_c[name] = bw
            packed_b[comp] = packed_c
        packed_blocks[bname] = packed_b
    return PackedModel(blocks=packed_blocks, manifest=manifest,
                       shards=shards)
