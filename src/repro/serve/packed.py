"""Whole-stack packed-model subsystem: prune once, pack once, stream
bitmap-compressed on every decode step.

``pack_model`` walks the params tree (the ``param_shapes`` inventory,
stacked over periods) and, for every serve-time
projection with a compressed dispatch path — attention ``wq/wk/wv/wo``
and MLP ``w_gate/w_up/w_down`` — selects the largest valid ``(BK, BN)``
bitmap tile and packs the (already pruned) tensor, period-stacked, into
one ``BitmapWeight`` per tensor.  The result is a pytree mirroring
``params["blocks"]`` (``BitmapWeight`` leaves where packed, ``None``
where dense) that threads through ``build_serve_step`` → ``decode_step``
→ ``decode_hidden`` → ``layers.mlp`` / ``_decode_attn``, so the per-step
matmuls dispatch via ``kernels/ops.bitmap_spmm`` instead of dense ``@``.

Every tensor that cannot pack falls back to dense *with a recorded
reason* (no valid tile, not a 2-D projection, no compressed dispatch
path yet, …) in a per-tensor manifest that also carries the modeled
per-step HBM bytes — sparse (bitmap) vs dense — which
``ServeEngine.report()`` aggregates across the whole stack.  This is the
paper's regime end-to-end: EIE runs *every* FC layer from compressed
storage; here the entire decode stack streams the bitmap format.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sparse.format import BitmapWeight, pack_bitmap_stacked

# (component, tensor) pairs with a compressed dispatch path in the decode
# step.  Everything else records a fallback reason in the manifest.
DISPATCHABLE = {
    ("attn", "wq"), ("attn", "wk"), ("attn", "wv"), ("attn", "wo"),
    ("mlp", "w_gate"), ("mlp", "w_up"), ("mlp", "w_down"),
}


def choose_block(k: int, n: int, cap: int = 128
                 ) -> Optional[Tuple[int, int]]:
    """Largest (BK, BN) bitmap tile dividing (k, n); BN % 8 == 0."""
    bk = next((d for d in range(min(k, cap), 0, -1) if k % d == 0), None)
    bn = next((d for d in range(min(n, cap), 0, -1)
               if n % d == 0 and d % 8 == 0), None)
    if bk is None or bn is None:
        return None
    return bk, bn


@dataclasses.dataclass
class PackEntry:
    """Manifest row: one tensor's pack decision + modeled per-step bytes."""

    path: str
    shape: Tuple[int, ...]
    packed: bool
    reason: str                      # "" when packed, else why dense
    block: Optional[Tuple[int, int]]
    sparsity: float                  # measured zero fraction
    sparse_bytes: int                # streamed per step on the chosen path
    dense_bytes: int


@dataclasses.dataclass
class PackedModel:
    """The packed pytree + its manifest and aggregate traffic model."""

    blocks: Dict                     # mirrors params["blocks"]
    manifest: List[PackEntry]

    @property
    def packed_entries(self) -> List[PackEntry]:
        return [e for e in self.manifest if e.packed]

    @property
    def fallback_entries(self) -> List[PackEntry]:
        return [e for e in self.manifest if not e.packed]

    def stream_report(self) -> Dict:
        """Modeled per-step weight-HBM bytes across the stack (no head —
        the engine adds its head term on top)."""
        sparse = sum(e.sparse_bytes for e in self.manifest)
        dense = sum(e.dense_bytes for e in self.manifest)
        return {
            "sparse_bytes_per_step": sparse,
            "dense_bytes_per_step": dense,
            "reduction": dense / sparse if sparse else 1.0,
            "packed_tensors": len(self.packed_entries),
            "fallback_tensors": len(self.fallback_entries),
            "fallbacks": {e.path: e.reason for e in self.fallback_entries},
        }


def _pack_leaf(path: str, comp: str, name: str, w, cap: int,
               cache_dense: bool) -> Tuple[PackEntry, Optional[BitmapWeight]]:
    arr = np.asarray(w)
    dense_bytes = arr.size * arr.dtype.itemsize
    sparsity = 1.0 - np.count_nonzero(arr) / max(arr.size, 1)

    def fallback(reason: str) -> Tuple[PackEntry, None]:
        return PackEntry(path=path, shape=arr.shape, packed=False,
                         reason=reason, block=None, sparsity=sparsity,
                         sparse_bytes=dense_bytes,
                         dense_bytes=dense_bytes), None

    if (comp, name) not in DISPATCHABLE:
        return fallback("no compressed dispatch path")
    if arr.ndim != 3:                # (P, K, N) = period-stacked projection
        return fallback(f"not a 2-D projection (ndim={arr.ndim - 1})")
    _, k, n = arr.shape
    block = choose_block(k, n, cap)
    if block is None:
        return fallback(f"no (BK, BN) tile divides ({k}, {n}) with BN % 8")
    bw = pack_bitmap_stacked(arr, block=block, cache_dense=cache_dense)
    entry = PackEntry(path=path, shape=arr.shape, packed=True, reason="",
                      block=block, sparsity=sparsity,
                      sparse_bytes=bw.hbm_bytes, dense_bytes=dense_bytes)
    return entry, bw


def pack_model(params: Dict, cap: int = 128,
               cache_dense: bool = False) -> PackedModel:
    """Pack every dispatchable serve-time projection of ``params``.

    Packing is lossless (per-tensor budget = max tile non-zero count), so
    the packed stream is numerically identical to dense dispatch — the
    compression comes from whatever pruning already happened upstream
    (``global_l1_prune`` in the engine).

    ``cache_dense`` attaches a pack-time dense rendering per tensor for
    the xla oracle dispatch (decompression is a pack-time cost off-TPU;
    it never counts toward the modeled HBM bytes) — the engine enables
    it when the resolved kernel impl is "xla".
    """
    manifest: List[PackEntry] = []
    packed_blocks: Dict = {}
    for bname, bdict in params["blocks"].items():
        packed_b: Dict = {}
        for comp, tensors in bdict.items():
            packed_c: Dict = {}
            for name, w in tensors.items():
                path = f"blocks/{bname}/{comp}/{name}"
                entry, bw = _pack_leaf(path, comp, name, w, cap, cache_dense)
                manifest.append(entry)
                packed_c[name] = bw
            packed_b[comp] = packed_c
        packed_blocks[bname] = packed_b
    return PackedModel(blocks=packed_blocks, manifest=manifest)
