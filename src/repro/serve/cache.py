"""Slotted KV/state-cache manager.

One ``init_cache`` allocation (batch = num_slots) lives for the whole
engine lifetime; every cache leaf carries the batch dimension at axis 1
(axis 0 is the period-stacked layer dim), so retiring a request and
admitting the next into the same slot is a single batched zero-write —
storage is *reused* across request lifetimes, never reallocated.  The
decode step donates the cache buffers, so steady-state serving does no
cache allocation at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache


class SlotKVCache:
    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int):
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, num_slots, max_len)
        self.resets = 0
        # one jitted executable for every slot (slot is traced) with the
        # old buffers donated: admission zeroes one line in place instead
        # of re-materialising the whole cache leaf by leaf
        self._reset = jax.jit(
            lambda cache, slot: jax.tree.map(
                lambda a: a.at[:, slot].set(0), cache),
            donate_argnums=(0,))

    def warmup(self) -> None:
        """Compile the reset executable (slot is traced: one compile)."""
        self.cache = self._reset(self.cache, jnp.int32(0))

    def reset_slot(self, slot: int) -> None:
        """Zero one slot's lines across every layer/leaf (fresh request)."""
        assert 0 <= slot < self.num_slots
        self.cache = self._reset(self.cache, jnp.int32(slot))
        self.resets += 1

    def register_metrics(self, reg) -> None:
        """Expose the contiguous cache's counters as registry gauges."""
        reg.gauge("kv.resets", lambda: self.resets)
        reg.gauge("kv.reserved_bytes", self.reserved_kv_bytes)

    def reserved_kv_bytes(self) -> int:
        """Bytes reserved for attention KV lines — the worst-case
        ``num_slots × capacity`` contiguous reservation the paged layout
        (repro.serve.paging) replaces."""
        total = 0
        for leaf in self.cache.values():
            for name in ("k", "v"):
                if name in leaf:
                    a = leaf[name]
                    total += int(a.size) * a.dtype.itemsize
        return total
