"""Deterministic fault injection + step-level invariant auditing for the
serving engine.

SparTen-style sparse datapaths fail *subtly*: a corrupted index or value
tile doesn't crash, it silently serves garbage, and an allocator whose
refcounts drift leaks pages long before anything visibly breaks.  This
module is the software analogue of a hardware fault campaign — every
recovery path in ``serve/`` gets exercised on demand, deterministically:

* ``FaultPlan`` — a seeded schedule of injected faults, fired by the
  engine at the top of each step.  Five fault kinds cover the engine's
  failure surface:

  - ``page_squeeze``: confiscate free pages (restored after
    ``duration`` steps) — drives out-of-pages admission queueing
    (strict mode) or preemption storms (``preempt=True``);
  - ``force_preempt``: preempt the youngest non-pinned slot(s) —
    drives requeue/replay regardless of pool pressure;
  - ``evict_storm``: flush the entire shared-prefix cache — drives
    cold re-registration and COW bookkeeping after mass eviction;
  - ``nan_logits``: poison the packed LM head's value payload (and its
    ``dense_cache`` — the xla oracle path reads it) with NaN — drives
    the sampler-corruption detection path;
  - ``bitflip``: flip one bit in a seeded packed tensor's value or
    bitmap array (mirrored into ``dense_cache``) — drives per-tensor
    integrity detection and dense quarantine.

  Faults mutate *weights and allocator state only* — never the request
  queue — so with ``audit=True`` every fault is recoverable and the
  served tokens stay bit-identical to a fault-free run (packing is
  lossless, replay is deterministic, quarantine falls back to the same
  numerics).  That equivalence is the chaos suite's core assertion.

* ``InvariantAuditor`` — the ``audit=True`` knob's engine-side checker.
  Once per step it audits the scheduler's slot bookkeeping, the page
  allocator (refcount conservation, free xor referenced, table
  aliasing), the prefill planner, request-state legality, and logits
  finiteness; and it keeps pack-time CRC32 checksums of every packed
  tensor so ``integrity_scan()`` can attribute corruption to a specific
  tensor for quarantine.  Violations raise ``AuditViolation`` — an
  audit failure is a bug, never control flow.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, TYPE_CHECKING

import jax.numpy as jnp
import numpy as np

from repro.serve.errors import AuditViolation
from repro.serve.request import TERMINAL_STATES, RequestState

if TYPE_CHECKING:                     # pragma: no cover - typing only
    from repro.serve.engine import ServeEngine

FAULT_KINDS = ("page_squeeze", "force_preempt", "evict_storm",
               "nan_logits", "bitflip")


@dataclasses.dataclass
class Fault:
    """One scheduled fault.  ``step`` is the engine step at whose start
    it fires; the remaining fields are kind-specific knobs."""

    step: int
    kind: str
    pages: int = 4        # page_squeeze: pages confiscated per pool
    duration: int = 4     # page_squeeze: steps until pages are restored
    count: int = 1        # force_preempt: victims this firing
    tensor: Optional[str] = None  # bitflip: target path (None = seeded)
    field: str = "values"         # bitflip: "values" or "bitmap"

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Build one with the per-kind helpers (``page_squeeze(step=...)`` …)
    or ``FaultPlan.chaos(seed, horizon)`` for one of each kind at seeded
    steps; pass it to ``ServeEngine(..., faults=plan)``.  The engine
    calls ``fire`` at the top of every step; everything the plan did (or
    skipped, with a reason) lands in ``plan.log``.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.faults: List[Fault] = []
        self.log: List[Dict] = []
        self._rng = np.random.default_rng(seed)
        self._restores: List[int] = []   # steps at which to restore pages

    # ------------------------------------------------------- schedule ----

    def add(self, fault: Fault) -> "FaultPlan":
        self.faults.append(fault)
        return self

    def page_squeeze(self, step: int, pages: int = 4,
                     duration: int = 4) -> "FaultPlan":
        return self.add(Fault(step, "page_squeeze", pages=pages,
                              duration=duration))

    def force_preempt(self, step: int, count: int = 1) -> "FaultPlan":
        return self.add(Fault(step, "force_preempt", count=count))

    def evict_storm(self, step: int) -> "FaultPlan":
        return self.add(Fault(step, "evict_storm"))

    def nan_logits(self, step: int) -> "FaultPlan":
        return self.add(Fault(step, "nan_logits"))

    def bitflip(self, step: int, tensor: Optional[str] = None,
                field: str = "values") -> "FaultPlan":
        assert field in ("values", "bitmap")
        return self.add(Fault(step, "bitflip", tensor=tensor, field=field))

    @classmethod
    def chaos(cls, seed: int = 0, horizon: int = 48) -> "FaultPlan":
        """One of every fault kind at seeded steps inside ``horizon``."""
        plan = cls(seed)
        rng = np.random.default_rng(seed)
        lo, hi = max(2, horizon // 8), max(3, horizon - 4)
        steps = sorted(int(s) for s in rng.integers(lo, hi,
                                                    len(FAULT_KINDS)))
        plan.page_squeeze(steps[0], pages=int(rng.integers(2, 6)),
                          duration=int(rng.integers(2, 8)))
        plan.force_preempt(steps[1], count=int(rng.integers(1, 3)))
        plan.evict_storm(steps[2])
        plan.nan_logits(steps[3])
        plan.bitflip(steps[4],
                     field="values" if rng.integers(2) else "bitmap")
        return plan

    # ----------------------------------------------------------- fire ----

    def fire(self, engine: "ServeEngine", step: int) -> None:
        """Inject every fault scheduled for ``step`` (and restore any
        page squeeze whose duration elapsed).  Called by the engine at
        the top of the step, before admission."""
        due_restores = [s for s in self._restores if s <= step]
        if due_restores:
            self._restores = [s for s in self._restores if s > step]
            n = engine.kv.restore_held() if engine.page_len else 0
            self.log.append({"step": step, "kind": "page_restore",
                             "pages": n})
        for f in self.faults:
            if f.step == step:
                getattr(self, f"_fire_{f.kind}")(engine, f, step)

    def _skip(self, step: int, kind: str, reason: str) -> None:
        self.log.append({"step": step, "kind": kind, "fired": False,
                         "reason": reason})

    def _fire_page_squeeze(self, engine, f: Fault, step: int) -> None:
        if not engine.page_len:
            return self._skip(step, f.kind, "engine is not paged")
        taken = engine.kv.confiscate(f.pages)
        self._restores.append(step + max(1, f.duration))
        self.log.append({"step": step, "kind": f.kind, "fired": True,
                         "pages": taken, "until": step + f.duration})

    def _fire_force_preempt(self, engine, f: Fault, step: int) -> None:
        fired = 0
        for _ in range(f.count):
            victims = [s for s in engine.scheduler.active
                       if not engine._pinned(s)]
            if not victims:
                break
            victim = max(victims, key=lambda s: engine._admit_seq[s])
            engine._preempt_slot(victim)
            engine._c_forced_preempts.inc()
            fired += 1
        if fired:
            self.log.append({"step": step, "kind": f.kind, "fired": True,
                             "count": fired})
        else:
            self._skip(step, f.kind, "no preemptable active slot")

    def _fire_evict_storm(self, engine, f: Fault, step: int) -> None:
        if not engine.page_len or not engine.prefix_reuse:
            return self._skip(step, f.kind, "prefix reuse not enabled")
        n = engine.kv.flush_prefix()
        self.log.append({"step": step, "kind": f.kind, "fired": True,
                         "evicted_blocks": n})

    def _fire_nan_logits(self, engine, f: Fault, step: int) -> None:
        bw = engine.lm_weight
        if bw is None:
            return self._skip(step, f.kind,
                              "no packed LM head to poison")
        engine.lm_weight = dataclasses.replace(
            bw,
            values=jnp.full_like(bw.values, jnp.nan),
            dense_cache=(jnp.full_like(bw.dense_cache, jnp.nan)
                         if bw.dense_cache is not None else None))
        self.log.append({"step": step, "kind": f.kind, "fired": True,
                         "tensor": "lm_head"})

    def _fire_bitflip(self, engine, f: Fault, step: int) -> None:
        if engine.packed is None:
            return self._skip(step, f.kind, "no packed stack")
        leaves = engine.packed.leaves()
        if not leaves:
            return self._skip(step, f.kind, "every tensor already dense")
        if f.tensor is not None:
            hit = [(p, bw) for p, bw in leaves if p == f.tensor]
            if not hit:
                return self._skip(step, f.kind,
                                  f"{f.tensor} not packed")
            path, bw = hit[0]
        else:
            path, bw = leaves[int(self._rng.integers(len(leaves)))]
        arr = bw.values if f.field == "values" else bw.packed_bits
        host = np.array(arr)
        flat = host.view(np.uint8).reshape(-1)
        bit = int(self._rng.integers(flat.size * 8))
        flat[bit // 8] ^= np.uint8(1 << (bit % 8))
        fields = {f.field if f.field == "values" else "packed_bits":
                  jnp.asarray(host)}
        if bw.dense_cache is not None:
            # the xla oracle dispatches dense_cache, so mirror some
            # corruption there too — which tensor is corrupt is what
            # matters (detection is via the canonical packed arrays)
            dc = np.array(bw.dense_cache)
            dcf = dc.view(np.uint8).reshape(-1)
            dcf[bit // 8 % dcf.size] ^= np.uint8(1 << (bit % 8))
            fields["dense_cache"] = jnp.asarray(dc)
        engine.packed.replace_leaf(path,
                                   dataclasses.replace(bw, **fields))
        self.log.append({"step": step, "kind": f.kind, "fired": True,
                         "tensor": path, "field": f.field, "bit": bit})

    # --------------------------------------------------------- report ----

    def register_metrics(self, reg) -> None:
        """Expose the plan's firing counts as registry gauges."""
        reg.gauge("faults.planned", lambda: len(self.faults))
        reg.gauge("faults.fired",
                  lambda: sum(1 for e in self.log if e.get("fired")))
        reg.gauge("faults.skipped",
                  lambda: sum(1 for e in self.log if not e.get("fired")))

    def summary(self) -> Dict:
        fired = [e for e in self.log if e.get("fired")]
        by_kind: Dict[str, int] = {}
        for e in fired:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
        return {"seed": self.seed, "planned": len(self.faults),
                "fired": len(fired),
                "skipped": len(self.log) - len(fired), "by_kind": by_kind,
                "log": list(self.log)}


def _checksum(bw) -> int:
    """CRC32 over a BitmapWeight's canonical arrays (bits + values +
    row starts); ``dense_cache`` is a derived rendering and excluded."""
    crc = 0
    for arr in (bw.packed_bits, bw.values, bw.row_start):
        crc = zlib.crc32(np.asarray(arr).tobytes(), crc)
    return crc


class InvariantAuditor:
    """The engine's ``audit=True`` checker: per-step structural
    invariants plus packed-tensor integrity attribution."""

    def __init__(self, engine: "ServeEngine"):
        self.engine = engine
        self.steps_checked = 0
        self.integrity_scans = 0
        self._sums: Dict[str, int] = {}
        if engine.packed is not None:
            for path, bw in engine.packed.leaves():
                self._sums[path] = _checksum(bw)
        if engine.lm_weight is not None:
            self._sums["lm_head"] = _checksum(engine.lm_weight)

    def drop(self, path: str) -> None:
        """Forget a quarantined tensor's checksum (it no longer has a
        packed representation to verify)."""
        self._sums.pop(path, None)

    # ------------------------------------------------------ integrity ----

    def integrity_scan(self) -> List[str]:
        """Paths whose packed arrays no longer match their pack-time
        checksum, or carry non-finite values — the quarantine list."""
        self.integrity_scans += 1
        eng = self.engine
        live = dict(eng.packed.leaves()) if eng.packed is not None else {}
        if eng.lm_weight is not None:
            live["lm_head"] = eng.lm_weight
        bad = []
        for path, bw in live.items():
            want = self._sums.get(path)
            if want is None:
                continue
            vals = np.asarray(bw.values).astype(np.float32)
            if _checksum(bw) != want or not np.isfinite(vals).all():
                bad.append(path)
        return bad

    # ----------------------------------------------------- invariants ----

    def check_step(self) -> None:
        """Audit every structural invariant after an engine step."""
        eng = self.engine
        eng.scheduler.audit()
        if eng.page_len:
            eng.kv.audit()
        if eng.planner is not None:
            eng.planner.audit(set(eng.scheduler.active))
        ingest = set(eng._ingest)
        active = set(eng.scheduler.active)
        if ingest != active:
            raise AuditViolation(
                f"ingest bookkeeping drift: ingest slots "
                f"{sorted(ingest)} != active {sorted(active)}")
        for slot, req in eng.scheduler.active.items():
            if len(req.tokens) > req.max_new_tokens:
                raise AuditViolation(
                    f"rid {req.rid} over-generated: {len(req.tokens)} > "
                    f"{req.max_new_tokens}")
        for req in eng.requests:
            if req.state not in TERMINAL_STATES:
                raise AuditViolation(
                    f"retired rid {req.rid} in non-terminal state "
                    f"{req.state.value}")
            if req.state is RequestState.DONE and req.error is not None:
                raise AuditViolation(
                    f"DONE rid {req.rid} carries error {req.error!r}")
        self.steps_checked += 1

    def check_logits(self, logits: np.ndarray, rows: List[int]) -> None:
        """Finite-logits invariant for the step's decoding rows.  Runs
        only after the integrity scan came back clean, so a violation
        here means corruption with no attributable tensor."""
        if not rows:
            return
        if not np.isfinite(logits[rows]).all():
            raise AuditViolation(
                "non-finite logits with no corrupted packed tensor to "
                "quarantine (rows %s)" % rows)

    def register_metrics(self, reg) -> None:
        reg.gauge("audit.steps_checked", lambda: self.steps_checked)
        reg.gauge("audit.integrity_scans", lambda: self.integrity_scans)
        reg.gauge("audit.checksummed_tensors", lambda: len(self._sums))

    def report(self) -> Dict:
        return {"enabled": True, "steps_checked": self.steps_checked,
                "integrity_scans": self.integrity_scans,
                "checksummed_tensors": len(self._sums)}
