"""Continuous-batching serving engine over the sparse decode stack.

The engine turns the straight-line ``serve()`` loop into a serving
system:

* a request queue with per-request prompt / generation-budget state
  (``repro.serve.request``);
* a slot scheduler that admits new requests into freed batch slots
  mid-flight — no drain barrier, decode keeps running at full batch
  width under a stream of arrivals (``repro.serve.scheduler``);
* a slotted KV-cache manager that reuses one donated ``init_cache``
  allocation across request lifetimes (``repro.serve.cache``);
* weights pruned once (``global_l1_prune``) and the LM head packed once
  into the paper's ``BitmapWeight`` format, dispatched through
  ``kernels/ops.bitmap_spmm`` every step — the bitmap-compressed HBM
  path runs end-to-end at serve time.

Positions are per-slot: the decode step takes a (B,) position vector so
each slot advances through its own sequence independently (the models
layer grew vector-position support for exactly this).
"""
from __future__ import annotations

import math
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_elastic_mesh
from repro.launch.steps import build_serve_step
from repro.models.config import ModelConfig
from repro.models.model import init_params, lm_head_weight
from repro.serve.cache import SlotKVCache
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import SlotScheduler
from repro.serve.trace import percentiles
from repro.sparse.format import BitmapWeight, pack_bitmap
from repro.sparse.pruning import global_l1_prune, per_tensor_prune, \
    sparsity_of


def _head_block(d_model: int, vocab: int,
                cap: int = 128) -> Optional[Tuple[int, int]]:
    """Largest (BK, BN) bitmap tile that divides the head; BN % 8 == 0."""
    bk = next((d for d in range(min(d_model, cap), 0, -1)
               if d_model % d == 0), None)
    bn = next((d for d in range(min(vocab, cap), 0, -1)
               if vocab % d == 0 and d % 8 == 0), None)
    if bk is None or bn is None:
        return None
    return bk, bn


def pack_lm_head(params, cfg: ModelConfig, sparsity: float = 0.0
                 ) -> Optional[BitmapWeight]:
    """Prune (per-tensor) + pack the (D, V) LM head once for serving."""
    block = _head_block(cfg.d_model, cfg.vocab_size)
    if block is None:
        return None
    w = lm_head_weight(params, cfg)
    if sparsity > 0:
        w = per_tensor_prune(w, sparsity)
    return pack_bitmap(np.asarray(w.astype(jnp.float32)), block=block)


class ServeEngine:
    """Continuous-batching decode over ``num_slots`` batch slots."""

    def __init__(self, cfg: ModelConfig, *, num_slots: int = 4,
                 max_len: int = 128, sparsity: float = 0.0, seed: int = 0,
                 model_parallel: int = 1, impl: Optional[str] = None,
                 bitmap_head: bool = True,
                 head_sparsity: Optional[float] = None):
        """``head_sparsity``: ``global_l1_prune`` deliberately keeps
        (tied) embeddings dense, so the LM head is additionally pruned
        per-tensor to this level before packing — that is what gives the
        bitmap head its compression at serve time.  Defaults to
        ``sparsity``; pass 0.0 to stream the exact dense head through the
        bitmap path instead (compression < 1, numerics identical to the
        dense head)."""
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.sparsity = sparsity
        self.mesh = make_elastic_mesh(model_parallel)

        params = init_params(jax.random.PRNGKey(seed), cfg)
        if sparsity > 0:
            params = global_l1_prune(params, sparsity)
        self.weight_sparsity = sparsity_of(params) if sparsity > 0 else 0.0
        pspecs = shd.named(self.mesh, shd.param_specs(cfg, self.mesh))
        self.params = jax.device_put(params, pspecs)

        # pack once, cache on the engine: every decode step streams the
        # head through the bitmap-compressed kernels/ops path
        self.head_sparsity = (sparsity if head_sparsity is None
                              else head_sparsity)
        self.lm_weight = (pack_lm_head(self.params, cfg, self.head_sparsity)
                          if bitmap_head else None)
        self.head_compression = (self.lm_weight.compression
                                 if self.lm_weight is not None else 1.0)

        self.scheduler = SlotScheduler(num_slots)
        self.kv = SlotKVCache(cfg, num_slots, max_len)
        step_fn = build_serve_step(cfg, impl=impl)
        self._jit_step = jax.jit(step_fn, donate_argnums=(1,))

        self._rng = np.random.default_rng(seed)
        self._tok = np.zeros(num_slots, np.int32)
        self._pos = np.zeros(num_slots, np.int32)
        self._warm = False
        self._steps = 0
        self._active_slot_steps = 0     # occupancy accounting
        self._next_rid = 0
        self.requests: List[Request] = []
        self._t0: Optional[float] = None

    @classmethod
    def from_arch(cls, arch: str, smoke: bool = True, **kw) -> "ServeEngine":
        cfg = get_smoke_config(arch) if smoke else get_config(arch)
        return cls(cfg, **kw)

    # ------------------------------------------------------------ intake ----

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0) -> Request:
        prompt = [int(t) for t in prompt]
        assert prompt, "empty prompt"
        assert len(prompt) + max_new_tokens - 1 <= self.max_len, (
            f"prompt {len(prompt)} + {max_new_tokens} new tokens exceeds "
            f"max_len {self.max_len}")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival=arrival)
        self._next_rid += 1
        self.requests.append(req)
        self.scheduler.submit(req)
        return req

    # ------------------------------------------------------------- loop ----

    def _wall(self) -> float:
        return time.perf_counter() - self._t0

    def _decode(self, tok: jnp.ndarray, pos: jnp.ndarray):
        if self.cfg.frontend == "frames":
            emb = jnp.asarray(self._rng.standard_normal(
                (self.num_slots, 1, self.cfg.d_model)), jnp.float32)
            return self._jit_step(self.params, self.kv.cache, None, pos,
                                  embeds=emb, lm_weight=self.lm_weight)
        return self._jit_step(self.params, self.kv.cache, tok, pos,
                              lm_weight=self.lm_weight)

    def warmup(self) -> None:
        """Compile the decode step + slot reset before the latency clock
        starts — otherwise the first request's percentiles measure XLA
        compile time, not serving.  Slots are all idle here; whatever the
        throwaway step writes at position 0 is zeroed again on admission.
        """
        if self._warm:
            return
        nxt, _, cache = self._decode(jnp.asarray(self._tok[:, None]),
                                     jnp.asarray(self._pos))
        jax.block_until_ready(nxt)
        self.kv.cache = cache
        self.kv.warmup()
        self._warm = True

    def step(self) -> None:
        """One full-batch decode step: admit, decode, route outputs."""
        self.warmup()
        if self._t0 is None:
            self._t0 = time.perf_counter()
        now = float(self._steps)
        for r in self.scheduler.waiting:
            if r.arrival <= now and r.t_due is None:
                r.t_due = self._wall()
        for slot, req in self.scheduler.admit(now):
            self.kv.reset_slot(slot)
            self._pos[slot] = 0
            self._tok[slot] = req.prompt[0]
            req.admit_step = self._steps
            if req.t_due is None:
                req.t_due = self._wall()

        nxt, _, cache = self._decode(jnp.asarray(self._tok[:, None]),
                                     jnp.asarray(self._pos))
        self.kv.cache = cache
        nxt_host = np.asarray(nxt)
        wall = self._wall()

        self._active_slot_steps += self.scheduler.num_active
        for slot, req in list(self.scheduler.active.items()):
            p = int(self._pos[slot])
            self._pos[slot] = p + 1
            if p + 1 < len(req.prompt):
                # still consuming the prompt: teacher-force the next token
                self._tok[slot] = req.prompt[p + 1]
                continue
            t = int(nxt_host[slot])
            req.tokens.append(t)
            if req.t_first is None:
                req.t_first = wall
            self._tok[slot] = t
            if (len(req.tokens) >= req.max_new_tokens
                    or p + 1 >= self.max_len):
                req.t_done = wall
                req.done_step = self._steps
                self.scheduler.release(slot)
                self._pos[slot] = 0
        self._steps += 1

    def run(self) -> dict:
        """Drive until every submitted request has drained; report stats."""
        self.warmup()
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while self.scheduler.has_work:
            if not self.scheduler.active:
                # idle: fast-forward the step clock to the next arrival
                nxt = self.scheduler.next_arrival()
                if nxt > self._steps:
                    self._steps = int(math.ceil(nxt))
            self.step()
        return self.report()

    # ---------------------------------------------------------- reports ----

    def report(self) -> dict:
        done = [r for r in self.requests if r.state == RequestState.DONE]
        dt = self._wall() if self._t0 is not None else 0.0
        gen = sum(len(r.tokens) for r in done)
        lat = percentiles([r.latency_s for r in done
                           if r.latency_s is not None])
        ftl = percentiles([r.first_token_s for r in done
                           if r.first_token_s is not None])
        occ = (self._active_slot_steps / (self._steps * self.num_slots)
               if self._steps else 0.0)
        return {
            "requests": len(done),
            "generated_tokens": gen,
            "steps": self._steps,
            "wall_s": dt,
            "tok_per_s": gen / dt if dt > 0 else float("nan"),
            "latency_s": lat,
            "first_token_s": ftl,
            "slot_occupancy": occ,
            "weight_sparsity": self.weight_sparsity,
            "head_compression": self.head_compression,
            "cache_resets": self.kv.resets,
        }
