"""Continuous-batching serving engine over the sparse decode stack.

The engine turns the straight-line ``serve()`` loop into a serving
system:

* a request queue with per-request prompt / generation-budget state
  (``repro.serve.request``);
* a slot scheduler that admits new requests into freed batch slots
  mid-flight — no drain barrier, decode keeps running at full batch
  width under a stream of arrivals (``repro.serve.scheduler``);
* a slotted KV-cache manager that reuses one donated ``init_cache``
  allocation across request lifetimes (``repro.serve.cache``) — or, with
  ``paged=True``, a paged KV cache (``repro.serve.paging``): fixed-size
  pages allocated lazily off a free list and gathered through per-slot
  page tables, so reserved cache bytes scale with live tokens instead of
  ``num_slots × max_len`` and out-of-pages admission queues instead of
  crashing;
* a chunked prefill subsystem (``repro.serve.prefill``): admitted
  prompts are ingested ``prefill_chunk`` tokens at a time through one
  batched ``build_prefill_step`` call per engine step — chunks from
  every mid-prefill request ride one padded ``(B, C)`` call, every
  projection dispatches at M = C through the packed weight stream, and
  decode keeps running between calls — instead of teacher-forcing each
  prompt through the decode step one position per step
  (``prefill_chunk=0`` keeps that legacy walk as the equivalence
  oracle);
* weights pruned once (``global_l1_prune``) and the *whole serve-time
  stack* packed once into the paper's ``BitmapWeight`` format
  (``repro.serve.packed.pack_model``): attention q/k/v/o, MLP
  gate/up/down, the MoE router + expert stacks, the mamba/rwkv mixer
  and channel-mix projections, and the LM head all dispatch through
  ``kernels/ops.bitmap_spmm`` (per-expert: ``bitmap_spmm_grouped``)
  every decode step — the bitmap-compressed HBM path runs end-to-end at
  serve time, and the per-tensor manifest records what packed vs fell
  back (and why).  DESIGN_PACKED.md documents the subsystem.

Positions are per-slot: the decode step takes a (B,) position vector so
each slot advances through its own sequence independently (the models
layer grew vector-position support for exactly this).
"""
from __future__ import annotations

import math
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_elastic_mesh
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models.config import ModelConfig
from repro.models.model import init_params, lm_head_weight
from repro.serve.cache import SlotKVCache
from repro.serve.packed import PackedModel, choose_block, pack_model
from repro.serve.paging import OutOfPages, PagedKVCache
from repro.serve.prefill import PrefillPlanner
from repro.serve.request import Request, RequestRejected
from repro.serve.scheduler import SlotScheduler
from repro.serve.trace import RollingStat
from repro.sparse.format import BitmapWeight, pack_bitmap
from repro.sparse.pruning import global_l1_prune, per_tensor_prune, \
    sparsity_of


def _head_block(d_model: int, vocab: int,
                cap: int = 128) -> Optional[Tuple[int, int]]:
    """Largest (BK, BN) bitmap tile that divides the head; BN % 8 == 0."""
    return choose_block(d_model, vocab, cap)


def pack_lm_head(params, cfg: ModelConfig, sparsity: float = 0.0,
                 cache_dense: bool = False) -> Optional[BitmapWeight]:
    """Prune (per-tensor) + pack the (D, V) LM head once for serving."""
    block = _head_block(cfg.d_model, cfg.vocab_size)
    if block is None:
        return None
    w = lm_head_weight(params, cfg)
    if sparsity > 0:
        w = per_tensor_prune(w, sparsity)
    return pack_bitmap(np.asarray(w.astype(jnp.float32)), block=block,
                       cache_dense=cache_dense)


class ServeEngine:
    """Continuous-batching decode over ``num_slots`` batch slots."""

    def __init__(self, cfg: ModelConfig, *, num_slots: int = 4,
                 max_len: int = 128, sparsity: float = 0.0, seed: int = 0,
                 model_parallel: int = 1, impl: Optional[str] = None,
                 bitmap_head: bool = True,
                 head_sparsity: Optional[float] = None,
                 stream_weights: bool = True, top_k: int = 0,
                 paged: bool = False, page_len: int = 16,
                 page_pool_tokens: Optional[int] = None,
                 prefill_chunk: int = 0, prefix_reuse: bool = False,
                 preempt: bool = False, history: int = 512):
        """``head_sparsity``: ``global_l1_prune`` deliberately keeps
        (tied) embeddings dense, so the LM head is additionally pruned
        per-tensor to this level before packing — that is what gives the
        bitmap head its compression at serve time.  Defaults to
        ``sparsity``; pass 0.0 to stream the exact dense head through the
        bitmap path instead (compression < 1, numerics identical to the
        dense head).

        ``stream_weights``: pack the whole decode stack (attention
        q/k/v/o, MLP gate/up/down, MoE router + expert stacks, SSM
        mixer / channel-mix projections) once via ``pack_model`` and
        stream it bitmap-compressed every step.  Packing is lossless, so
        tokens are identical to dense dispatch at any sparsity; pass
        False for a dense-dispatch baseline.

        ``top_k``: engine-default top-k truncation for sampled requests
        (0 = no truncation); each request may override it via
        ``submit(top_k=...)`` — the jitted sampler then applies a
        per-slot masked top-k (all-default serving keeps the static
        ``lax.top_k`` path; the first override costs one extra jit
        signature, mirroring how sampling itself engages).

        ``paged``: page the attention KV cache (``repro.serve.paging``)
        into ``page_len``-token pages gathered through per-slot page
        tables — reserved cache bytes scale with live tokens instead of
        ``num_slots × max_len``.  ``page_pool_tokens`` bounds each page
        pool (default: worst case, still lazily allocated); when pages
        run out, admission queues until retirements free pages.
        ``paged=False`` (or ``page_len=0``) keeps the contiguous layout.

        ``prefill_chunk``: ingest admitted prompts in batched
        ``prefill_chunk``-token chunks (one ``build_prefill_step`` call
        per engine step, chunks from every mid-prefill request batched
        together) instead of teacher-forcing them through decode steps
        one position at a time.  0 keeps the legacy teacher-forcing walk
        — the equivalence oracle: chunked prefill is token-identical to
        it.  Archs with recurrent mixer state (mamba/rwkv/rwkv_cm) or
        the frames frontend fall back to teacher-forcing with a recorded
        reason.

        ``prefix_reuse``: hash ``page_len``-token prompt blocks and map
        a new request's matching prefix onto already-resident physical
        pages copy-on-write (``repro.serve.paging`` prefix cache) — the
        matched region skips prefill entirely, so TTFT on a hit
        collapses to queue + first-decode.  Requires paging; archs with
        recurrent mixer state or the frames frontend fall back with a
        recorded reason (pages don't capture that state, so skipping
        ingestion would drop it).

        ``preempt``: recompute-on-preempt eviction.  Admission commits
        only the *live* ingest pages instead of the worst case
        (occupancy rises at equal pool size); when the free list runs
        dry mid-flight the engine evicts cached prefixes and then
        preempts the youngest slot — its pages return to the pool and
        the request re-queues at the head of the FIFO with prompt +
        already-generated tokens re-ingested on re-admission.  Sampling
        keys fold the absolute position, so recomputed requests emit
        token-identical streams.  Requires paging; the frames frontend
        falls back (its embeds derive from the global step counter, so
        a recompute would diverge).

        ``history``: retired requests kept for inspection (a bounded
        deque); latency aggregates are folded in at retire time
        (``RollingStat``), so a long-lived engine's memory and
        ``report()`` cost stay O(history), not O(total traffic).
        """
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.sparsity = sparsity
        self.mesh = make_elastic_mesh(model_parallel)

        params = init_params(jax.random.PRNGKey(seed), cfg)
        if sparsity > 0:
            params = global_l1_prune(params, sparsity)
        self.weight_sparsity = sparsity_of(params) if sparsity > 0 else 0.0
        pspecs = shd.named(self.mesh, shd.param_specs(cfg, self.mesh))
        self.params = jax.device_put(params, pspecs)

        # pack once, cache on the engine: every decode step streams the
        # stack + head through the bitmap-compressed kernels/ops path.
        # On the xla (non-TPU) dispatch the pack also renders the dense
        # oracle view, so serving pays no per-step software decompression.
        from repro.kernels.ops import default_impl
        cache_dense = (impl or default_impl()) == "xla"
        self.stream_fallback: Optional[str] = None
        mp_actual = int(self.mesh.shape.get("model", 1))
        if stream_weights and mp_actual > 1:
            # packed leaves are host-built (values are packed along
            # flattened tile dims, so the dense param_specs don't apply);
            # GSPMD would replicate the whole compressed stack per device,
            # regressing the sharded dense path's per-device memory —
            # fall back to dense dispatch until the packed format grows a
            # sharded layout
            stream_weights = False
            self.stream_fallback = (
                f"model_parallel={mp_actual}: no sharded layout for "
                f"packed weights yet; stack served dense")
            warnings.warn(f"whole-stack bitmap streaming fell back to "
                          f"dense: {self.stream_fallback}", stacklevel=2)
        elif not stream_weights:
            self.stream_fallback = "stream_weights=False"
        self.packed: Optional[PackedModel] = (
            pack_model(self.params, cache_dense=cache_dense)
            if stream_weights else None)
        self.head_sparsity = (sparsity if head_sparsity is None
                              else head_sparsity)
        self.head_fallback: Optional[str] = None
        if bitmap_head:
            self.lm_weight = pack_lm_head(self.params, cfg,
                                          self.head_sparsity,
                                          cache_dense=cache_dense)
            if self.lm_weight is None:
                self.head_fallback = (
                    f"no (BK, BN) tile divides (d_model={cfg.d_model}, "
                    f"vocab={cfg.vocab_size}) with BN % 8 == 0; "
                    f"head served dense")
                warnings.warn(f"bitmap LM head fell back to dense: "
                              f"{self.head_fallback}", stacklevel=2)
        else:
            self.lm_weight = None
            self.head_fallback = "disabled (bitmap_head=False)"
        self.head_compression = (self.lm_weight.compression
                                 if self.lm_weight is not None else 1.0)

        self.scheduler = SlotScheduler(num_slots, history=history)
        # paged KV cache: pages only help when some block caches per-token
        # KV lines, and the paged pools (like the packed weights) have no
        # sharded layout yet — fall back to contiguous with a reason
        self.paging_fallback: Optional[str] = None
        if not paged:
            page_len = 0
        elif mp_actual > 1:
            page_len = 0
            self.paging_fallback = (
                f"model_parallel={mp_actual}: no sharded layout for paged "
                f"KV pools yet; contiguous cache kept")
            warnings.warn(f"paged KV cache fell back to contiguous: "
                          f"{self.paging_fallback}", stacklevel=2)
        elif not any(b.mixer == "attn" for b in cfg.pattern):
            page_len = 0
            self.paging_fallback = (
                f"{cfg.name}: no attention blocks — recurrent state is "
                f"O(1)/slot, nothing to page")
            warnings.warn(f"paged KV cache fell back to contiguous: "
                          f"{self.paging_fallback}", stacklevel=2)
        self.page_len = page_len

        # shared-prefix reuse + preemption both live on the paged cache;
        # each falls back (recorded reason, same idiom as above) when its
        # preconditions don't hold rather than failing the engine
        recurrent = any(b.mixer != "attn" or b.ffn == "rwkv_cm"
                        for b in cfg.pattern)
        self.prefix_fallback: Optional[str] = None
        if prefix_reuse:
            if not page_len:
                self.prefix_fallback = (
                    "paged KV cache disabled (or fell back to "
                    "contiguous); no pages to share")
            elif cfg.frontend == "frames":
                self.prefix_fallback = (
                    f"{cfg.name}: frames frontend derives embeds from "
                    f"the step counter; prompt-token hashing is "
                    f"meaningless")
            elif recurrent:
                self.prefix_fallback = (
                    f"{cfg.name}: recurrent mixer state (mamba/rwkv) is "
                    f"not captured by KV pages; skipping ingestion "
                    f"would drop it")
            if self.prefix_fallback:
                prefix_reuse = False
                warnings.warn(f"shared-prefix reuse fell back: "
                              f"{self.prefix_fallback}", stacklevel=2)
        self.prefix_reuse = prefix_reuse
        self.preempt_fallback: Optional[str] = None
        if preempt:
            if not page_len:
                self.preempt_fallback = (
                    "paged KV cache disabled (or fell back to "
                    "contiguous); no pages to reclaim")
            elif cfg.frontend == "frames":
                self.preempt_fallback = (
                    f"{cfg.name}: frames embeds fold the global step "
                    f"counter, so a preempted request's recompute would "
                    f"diverge from its first run")
            if self.preempt_fallback:
                preempt = False
                warnings.warn(f"recompute-on-preempt fell back: "
                              f"{self.preempt_fallback}", stacklevel=2)
        self.preempt = preempt

        self.kv = (PagedKVCache(cfg, num_slots, max_len, page_len,
                                pool_tokens=page_pool_tokens,
                                strict=not preempt)
                   if page_len else SlotKVCache(cfg, num_slots, max_len))
        self.top_k_default = top_k
        step_fn = build_serve_step(cfg, impl=impl, top_k=top_k)
        self._jit_step = jax.jit(step_fn, donate_argnums=(1,))

        # chunked prefill: admitted prompts are ingested prefill_chunk
        # tokens at a time through one batched prefill call per engine
        # step; 0 keeps the legacy teacher-forced prompt walk (the
        # equivalence oracle).  Recurrent mixer state advances one token
        # per step by construction, and the frames frontend derives its
        # embeds from the step counter — both keep teacher-forcing with
        # a recorded reason, like the paging fallbacks above.
        self.prefill_fallback: Optional[str] = None
        if prefill_chunk > 0:
            if cfg.frontend == "frames":
                self.prefill_fallback = (
                    f"{cfg.name}: frames frontend derives per-step embeds "
                    f"from the step counter; nothing to prefill")
            elif any(b.mixer != "attn" or b.ffn == "rwkv_cm"
                     for b in cfg.pattern):
                self.prefill_fallback = (
                    f"{cfg.name}: recurrent mixer state (mamba/rwkv) has "
                    f"no chunked prefill path yet; teacher-forcing kept")
            if self.prefill_fallback:
                prefill_chunk = 0
                warnings.warn(f"chunked prefill fell back to "
                              f"teacher-forcing: {self.prefill_fallback}",
                              stacklevel=2)
        self.prefill_chunk = prefill_chunk
        self.planner: Optional[PrefillPlanner] = (
            PrefillPlanner(num_slots, prefill_chunk)
            if prefill_chunk else None)
        self._jit_prefill = (
            jax.jit(build_prefill_step(cfg, impl=impl),
                    donate_argnums=(1,)) if prefill_chunk else None)
        self._prefill_steps = 0
        self._decode_steps = 0

        self._tok = np.zeros(num_slots, np.int32)
        self._pos = np.zeros(num_slots, np.int32)
        # frames frontend: per-step embeddings come from a jax PRNG key
        # folded with the step counter *inside* the jitted step — the old
        # host-side standard_normal forced a host sync every decode step
        self._embed_key = jax.random.PRNGKey(seed + 0x5eed)
        # per-slot sampling state (greedy slots keep temperature 0).
        # _use_sampling stays False until some request asks for T > 0, so
        # all-greedy serving never pays the categorical/top-k machinery
        # (flipping it later costs one extra jit signature compile).
        self._use_sampling = False
        # the per-slot top-k vector (a full-vocab sort in the sampler)
        # only engages once some request *overrides* the engine default —
        # all-default serving keeps the cheaper static lax.top_k path
        self._use_topk_vec = False
        self._temp = np.zeros(num_slots, np.float32)
        self._topk = np.zeros(num_slots, np.int32)
        self._keys = np.zeros((num_slots, 2), np.uint32)
        self._seed = seed
        self._warm = False
        self._steps = 0
        self._active_slot_steps = 0     # occupancy accounting
        self._next_rid = 0
        # per-slot ingest = prompt + tokens generated before a preemption
        # — the teacher-forcing/prefill source, so a recomputed request
        # replays its own history instead of resampling it
        self._ingest: Dict[int, List[int]] = {}
        self._admit_seq = np.zeros(num_slots, np.int64)  # preempt order
        self._admit_counter = 0
        self._recomputed_tokens = 0
        # bounded retained history + streaming aggregates: report() reads
        # these instead of rescanning every request ever submitted
        self.history = history
        self.requests: deque = deque(maxlen=max(1, history))
        self._done_count = 0
        self._gen_tokens = 0
        self._lat_stat = RollingStat(seed=1)
        self._ftl_stat = RollingStat(seed=2)
        self._queue_stat = RollingStat(seed=3)
        self._prefill_stat = RollingStat(seed=4)
        self._fdec_stat = RollingStat(seed=5)
        self._ftl_hit = RollingStat(seed=6)
        self._ftl_miss = RollingStat(seed=7)
        self._t0: Optional[float] = None

    @classmethod
    def from_arch(cls, arch: str, smoke: bool = True, **kw) -> "ServeEngine":
        cfg = get_smoke_config(arch) if smoke else get_config(arch)
        return cls(cfg, **kw)

    # ------------------------------------------------------------ intake ----

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, temperature: float = 0.0,
               seed: Optional[int] = None,
               top_k: Optional[int] = None) -> Request:
        """``temperature`` > 0 samples this request's tokens with its own
        PRNG stream, seeded by ``seed`` (default: engine seed + rid); 0
        stays greedy.  ``top_k`` truncates *this request's* sampling
        (None: the engine default; 0: no truncation).

        Raises ``RequestRejected`` (typed, process keeps serving) when
        the request can never run: empty prompt, a generation budget
        below one token, budget beyond ``max_len``, or — under paging —
        a worst-case page need larger than the whole pool.  A merely
        *busy* engine never rejects; the request queues until slots (and
        pages) free up."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise RequestRejected("empty prompt")
        if max_new_tokens < 1:
            # the engine's done-check runs only after appending a token,
            # so a zero budget would quietly generate one anyway — reject
            # it typed instead of silently over-delivering
            raise RequestRejected(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        need = len(prompt) + max_new_tokens - 1
        if need > self.max_len:
            raise RequestRejected(
                f"prompt {len(prompt)} + {max_new_tokens} new tokens "
                f"exceeds max_len {self.max_len}")
        if self.page_len and not self.kv.possible(need):
            raise RequestRejected(
                f"prompt {len(prompt)} + {max_new_tokens} new tokens needs "
                f"more pages than the whole pool holds "
                f"(page_len={self.page_len}); raise page_pool_tokens")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival=arrival,
                      temperature=temperature, seed=seed, top_k=top_k)
        if temperature > 0:
            self._use_sampling = True
        if top_k is not None and top_k != self.top_k_default:
            self._use_topk_vec = True
        self._next_rid += 1
        # the scheduler owns the request until retirement; the engine's
        # bounded ``requests`` history only receives it when done (the
        # old append-on-submit list grew with total traffic forever)
        self.scheduler.submit(req)
        return req

    # ------------------------------------------------------------- loop ----

    def _wall(self) -> float:
        return time.perf_counter() - self._t0

    def _commit_tokens(self, req: Request) -> int:
        """Pages to commit at admission, in tokens.  Strict mode commits
        the worst case (prompt + full budget) so allocation can never
        fail mid-flight; preemptible mode commits only the *live* ingest
        (prompt + tokens already generated before a preemption) — more
        requests fit the same pool, and growth past the commitment is
        covered by recompute-on-preempt."""
        if self.preempt:
            return len(req.prompt) + len(req.tokens)
        return len(req.prompt) + req.max_new_tokens - 1

    def _with_pages(self, fn, requester: int):
        """Run a page-allocating call, resolving ``OutOfPages`` (raised
        only in preemptible mode, after the prefix cache has been
        drained) by preempting the youngest slot until it succeeds."""
        while True:
            try:
                return fn()
            except OutOfPages:
                self._reclaim(requester)

    def _reclaim(self, requester: int) -> None:
        victims = [s for s in self.scheduler.active if s != requester]
        # unreachable by construction: submit() checks possible(), and a
        # lone slot's own pages never exceed its capped worst case, so a
        # dry pool always implicates an evictable cache entry (already
        # drained) or another slot
        assert victims, "page pool exhausted with no preemptable slot"
        victim = max(victims, key=lambda s: int(self._admit_seq[s]))
        self._preempt_slot(victim)

    def _preempt_slot(self, slot: int) -> None:
        """Preempt: reclaim the slot's pages and re-queue its request at
        the head of the FIFO.  Everything computed so far is discarded;
        on re-admission the prompt + already-generated tokens re-ingest
        through the normal prefill path (vLLM-style recompute).  Decode
        sampling keys fold the absolute position, so the recomputed
        stream is token-identical to the undisturbed one."""
        req = self.scheduler.active[slot]
        req.t_preempt.append(self._wall())
        if self.planner is not None:
            self.planner.cancel(slot)
        self.scheduler.requeue(slot)
        self.kv.retire(slot)
        self._ingest.pop(slot, None)
        self._pos[slot] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0

    def _retire(self, req: Request) -> None:
        """Fold the finished request into the streaming aggregates and
        the bounded retained history — report() never rescans."""
        self._done_count += 1
        self._gen_tokens += len(req.tokens)
        self._lat_stat.add(req.latency_s)
        self._ftl_stat.add(req.first_token_s)
        self._queue_stat.add(req.queue_s)
        self._prefill_stat.add(req.prefill_s)
        self._fdec_stat.add(req.first_decode_s)
        (self._ftl_hit if req.prefix_hit_tokens > 0
         else self._ftl_miss).add(req.first_token_s)
        self.requests.append(req)

    def _decode(self, tok: jnp.ndarray, pos: jnp.ndarray):
        packed = self.packed.blocks if self.packed is not None else None
        kw = dict(lm_weight=self.lm_weight, packed=packed)
        if self.page_len:
            kw["page_tables"] = self.kv.tables()
        if self._use_sampling:
            kw.update(sample_keys=jnp.asarray(self._keys),
                      temperature=jnp.asarray(self._temp))
            if self._use_topk_vec:
                kw["top_ks"] = jnp.asarray(self._topk)
        if self.cfg.frontend == "frames":
            # device-side frame embeddings: fold the step counter into a
            # carried key — no host RNG (and no host sync) in the hot loop
            ekey = jax.random.fold_in(self._embed_key, self._steps)
            return self._jit_step(self.params, self.kv.cache, None, pos,
                                  embed_rng=ekey, **kw)
        return self._jit_step(self.params, self.kv.cache, tok, pos, **kw)

    def _prefill(self, tokens: np.ndarray, pos: np.ndarray,
                 lens: np.ndarray):
        """One jitted chunked-prefill call over the fixed (B, C) batch."""
        packed = self.packed.blocks if self.packed is not None else None
        kw = dict(packed=packed)
        if self.page_len:
            kw["page_tables"] = self.kv.tables()
        return self._jit_prefill(self.params, self.kv.cache,
                                 jnp.asarray(tokens), jnp.asarray(pos),
                                 jnp.asarray(lens), **kw)

    def _prefill_call(self) -> None:
        """Run the planner's next batched chunk call and route results.

        Under paging, every participating slot's chunk pages are
        bulk-mapped in one admission (``ensure_range``) before the call.
        Slots that finish their last chunk here flip to decode phase at
        position ``len(prompt) - 1`` — the next decode step consumes the
        final prompt token and samples the first generated token, just
        like the teacher-forcing path's last prompt step did.
        """
        tokens, pos, lens, finished = self.planner.next_call()
        if self.page_len:
            # oldest slots first: if mapping runs the pool dry in
            # preemptible mode, the youngest victims haven't mapped yet —
            # their reclaimed pages go to the older requesters (a
            # preempted slot's lane still scatters, into the trash page)
            order = sorted(np.nonzero(lens)[0],
                           key=lambda s: int(self._admit_seq[int(s)]))
            for slot in order:
                if int(slot) not in self.scheduler.active:
                    continue
                self._with_pages(
                    lambda s=int(slot): self.kv.ensure_range(
                        s, int(pos[s]), int(pos[s]) + int(lens[s])),
                    int(slot))
        hidden, cache = self._prefill(tokens, pos, lens)
        self.kv.cache = cache
        jax.block_until_ready(hidden)
        wall = self._wall()
        if self.prefix_reuse:
            # publish each advanced slot's fully-written blocks *now* —
            # before any later chunk can ring-wrap over them
            for slot in np.nonzero(lens)[0]:
                if int(slot) in self.scheduler.active:
                    self.kv.register_prefix(
                        int(slot), self._ingest[int(slot)],
                        int(pos[slot]) + int(lens[slot]))
        for slot in finished:
            if slot not in self.scheduler.active:
                continue               # preempted mid-call
            req = self.scheduler.active[slot]
            ing = self._ingest[slot]
            self._pos[slot] = len(ing) - 1
            self._tok[slot] = ing[-1]
            if req.t_prefill_done is None:
                req.t_prefill_done = wall
        for slot in np.nonzero(lens)[0]:
            if self.planner.in_prefill(int(slot)):
                # park the passenger's decode write on the next unwritten
                # prompt position: the next chunk rewrites that line
                # before anything reads it
                self._pos[slot] = self.planner.next_pos(int(slot))
        self._prefill_steps += 1

    def warmup(self) -> None:
        """Compile the decode step + slot reset before the latency clock
        starts — otherwise the first request's percentiles measure XLA
        compile time, not serving.  Slots are all idle here; whatever the
        throwaway steps write at position 0 is zeroed again on admission.

        Two throwaway decodes, not one: the first consumes the freshly
        allocated (uncommitted) cache, but its *output* cache carries the
        mesh's NamedSharding, which is a different jit signature — a
        single-step warmup left the steady-state executable to compile
        inside the first timed step (≈0.8 s mid-run for the packed
        stack).  The second call compiles the steady-state signature.
        """
        if self._warm:
            return
        for _ in range(2):
            nxt, _, cache = self._decode(jnp.asarray(self._tok[:, None]),
                                         jnp.asarray(self._pos))
            self.kv.cache = cache
        jax.block_until_ready(nxt)
        if self.prefill_chunk:
            # compile the prefill signature too: a throwaway call with
            # every lane masked (lens = 0) writes nothing — contiguous
            # lanes drop out of the scatter, paged lanes hit the trash
            # page — so the cache the first real step sees is untouched.
            # It runs after the decode warmup, so it consumes (and
            # yields) the steady-state committed-sharding cache.
            hidden, cache = self._prefill(
                np.zeros((self.num_slots, self.prefill_chunk), np.int32),
                np.zeros(self.num_slots, np.int32),
                np.zeros(self.num_slots, np.int32))
            self.kv.cache = cache
            jax.block_until_ready(hidden)
        self.kv.warmup()
        self._warm = True

    def step(self) -> None:
        """One engine step: admit, at most one batched prefill call, then
        the full-batch decode step (skipped only when every active slot
        is mid-prefill)."""
        self.warmup()
        if self._t0 is None:
            self._t0 = time.perf_counter()
        now = float(self._steps)
        for r in self.scheduler.waiting:
            if r.arrival <= now and r.t_due is None:
                r.t_due = self._wall()
        fits = None
        if self.page_len:
            # out-of-pages: the head-of-line request queues (strict FIFO)
            # until retirements free enough pages — never a crash.  The
            # gate *reserves* (check-and-commit), so multiple admissions
            # in one pass can't over-commit the pool.
            fits = lambda r: self.kv.reserve(self._commit_tokens(r))
        for slot, req in self.scheduler.admit(now, fits=fits):
            # ingest = prompt plus tokens generated before a preemption:
            # a recomputed request teacher-forces/prefills its own
            # history instead of resampling it
            ing = list(req.prompt) + list(req.tokens)
            self._admit_seq[slot] = self._admit_counter
            self._admit_counter += 1
            shared = 0
            if self.page_len:
                blocks = None
                if self.prefix_reuse:
                    _, blocks = self.kv.match_prefix(ing)
                shared = self.kv.admit(slot, self._commit_tokens(req),
                                       prefix=blocks)
            else:
                self.kv.reset_slot(slot)
            self._ingest[slot] = ing
            if not req.t_preempt:
                req.prefix_hit_tokens = shared
            else:
                # recompute cost actually paid on this re-admission
                # (adopted blocks — often this request's own earlier
                # registrations — shrink it)
                req.recomputed_tokens += max(0, len(ing) - 1 - shared)
                self._recomputed_tokens += max(0, len(ing) - 1 - shared)
            self._pos[slot] = shared
            self._tok[slot] = ing[shared]
            self._temp[slot] = req.temperature
            self._topk[slot] = (req.top_k if req.top_k is not None
                                else self.top_k_default)
            rseed = req.seed if req.seed is not None \
                else self._seed + 0x9e37 * (req.rid + 1)
            self._keys[slot] = np.asarray(jax.random.PRNGKey(rseed))
            req.admit_step = self._steps
            if req.t_due is None:
                req.t_due = self._wall()
            if req.t_admit is None:   # re-admissions keep the first mark
                req.t_admit = self._wall()
            if self.planner is not None:
                self.planner.start(slot, ing, start=shared)
            if shared >= len(ing) - 1 and req.t_prefill_done is None:
                # nothing left to ingest — single-token prompt, or a full
                # prefix hit: TTFT collapses to queue + first-decode
                req.t_prefill_done = req.t_admit

        # at most one prefill call per engine step: a stream of long
        # prompts interleaves chunk calls with decode steps instead of
        # starving the decoding slots
        prefilled = False
        if self.planner is not None and self.planner.has_work:
            self._prefill_call()
            prefilled = True

        in_prefill = (self.planner.in_prefill if self.planner is not None
                      else lambda s: False)
        decoding = [s for s in self.scheduler.active if not in_prefill(s)]
        if decoding or not prefilled:
            if self.page_len:
                # map each decoding slot's current write page; mid-prefill
                # passengers stay unmapped and scribble into the trash
                # page (or an unwritten line their next chunk rewrites).
                # Oldest first: in preemptible mode a dry pool preempts
                # the youngest slots, which haven't mapped yet
                for slot in sorted(decoding,
                                   key=lambda s: int(self._admit_seq[s])):
                    if slot not in self.scheduler.active:
                        continue
                    self._with_pages(
                        lambda s=slot: self.kv.ensure(
                            s, int(self._pos[s])), slot)
                decoding = [s for s in self.scheduler.active
                            if not in_prefill(s)]
            nxt, _, cache = self._decode(jnp.asarray(self._tok[:, None]),
                                         jnp.asarray(self._pos))
            self.kv.cache = cache
            nxt_host = np.asarray(nxt)
            wall = self._wall()

            self._active_slot_steps += len(decoding)
            for slot, req in list(self.scheduler.active.items()):
                if in_prefill(slot):
                    continue
                ing = self._ingest[slot]
                p = int(self._pos[slot])
                self._pos[slot] = p + 1
                if (self.prefix_reuse and (p + 1) % self.page_len == 0):
                    # a block boundary just filled: publish it (prompt
                    # *and* generated blocks — identical greedy requests
                    # reuse each other's generations too)
                    self.kv.register_prefix(slot, ing, p + 1)
                if p + 1 < len(ing):
                    # still consuming prompt/recompute history: teacher-
                    # force the next token (legacy walk, or a preempted
                    # request replaying its generated prefix)
                    self._tok[slot] = ing[p + 1]
                    if (p + 1 == len(ing) - 1
                            and req.t_prefill_done is None):
                        req.t_prefill_done = wall  # prompt cache resident
                    continue
                t = int(nxt_host[slot])
                req.tokens.append(t)
                ing.append(t)
                if req.t_first is None:
                    req.t_first = wall
                self._tok[slot] = t
                if (len(req.tokens) >= req.max_new_tokens
                        or p + 1 >= self.max_len):
                    req.t_done = wall
                    req.done_step = self._steps
                    self.scheduler.release(slot)
                    if self.page_len:
                        self.kv.retire(slot)   # pages back to the free list
                    self._ingest.pop(slot, None)
                    self._pos[slot] = 0
                    self._temp[slot] = 0.0     # freed slots decode greedy
                    self._topk[slot] = 0
                    self._retire(req)
            self._decode_steps += 1
        self._steps += 1

    def run(self) -> dict:
        """Drive until every submitted request has drained; report stats."""
        self.warmup()
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while self.scheduler.has_work:
            if not self.scheduler.active:
                # idle: fast-forward the step clock to the next arrival
                nxt = self.scheduler.next_arrival()
                if nxt > self._steps:
                    self._steps = int(math.ceil(nxt))
            self.step()
        return self.report()

    # ---------------------------------------------------------- reports ----

    def weight_stream_report(self) -> dict:
        """Modeled per-step weight-HBM bytes, sparse vs dense, aggregated
        across the whole decode stack (blocks + LM head).

        Embeddings are excluded: the token lookup gathers B rows, it does
        not stream the table.  The head term is the packed head's bitmap
        bytes, or its dense bytes when the head fell back.

        MoE expert stacks count once per *activated* expert per step —
        with ``num_slots`` slots each routing to ``top_k`` experts, a
        decode step touches at most ``min(E, num_slots × top_k)`` experts
        — not once per stored expert (accounting rule in
        DESIGN_PACKED.md §traffic model).
        """
        head_dense = (self.cfg.d_model * self.cfg.vocab_size
                      * np.dtype(np.float32).itemsize)
        head_sparse = (self.lm_weight.hbm_bytes
                       if self.lm_weight is not None else head_dense)
        activated = (self.num_slots * self.cfg.top_k
                     if self.cfg.num_experts else None)
        if self.packed is not None:
            rep = self.packed.stream_report(activated_experts=activated)
        else:
            # dense-dispatch baseline: same accounting rule, same code —
            # router-gated expert stacks stream once per activated expert
            from repro.serve.packed import ROUTED_EXPERT, activated_scale
            dense = 0
            for bdict in self.params["blocks"].values():
                for comp, tensors in bdict.items():
                    for name, leaf in tensors.items():
                        b = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                        routed = (leaf.shape[1]
                                  if (comp, name) in ROUTED_EXPERT
                                  and leaf.ndim == 4 else 0)
                        dense += int(round(
                            b * activated_scale(routed, activated)))
            rep = {"sparse_bytes_per_step": dense,
                   "dense_bytes_per_step": dense, "reduction": 1.0,
                   "packed_tensors": 0, "fallback_tensors": 0,
                   "activated_experts": activated,
                   "fallbacks": {"*": self.stream_fallback
                                 or "stream_weights=False"}}
        sparse = rep["sparse_bytes_per_step"] + head_sparse
        dense = rep["dense_bytes_per_step"] + head_dense
        return {**rep,
                "sparse_bytes_per_step": sparse,
                "dense_bytes_per_step": dense,
                "reduction": dense / sparse if sparse else 1.0}

    def prefill_report(self) -> dict:
        """The prefill section: chunk-call accounting + the step split."""
        rep = {"enabled": self.prefill_chunk > 0,
               "fallback": self.prefill_fallback,
               "prefill_steps": self._prefill_steps,
               "decode_steps": self._decode_steps}
        if self.planner is not None:
            rep.update(self.planner.report())
        else:
            rep.update({"chunk": 0, "calls": 0, "tokens_prefilled": 0,
                        "in_flight": 0, "lane_utilization": None})
        return rep

    def prefix_reuse_report(self) -> dict:
        """Shared-prefix + preemption stats: cache hit/evict/fork
        counters (from the paged cache), the hit-vs-miss TTFT split, and
        the preemption/recompute accounting."""
        rep = {
            "enabled": self.prefix_reuse,
            "fallback": self.prefix_fallback,
            "ttft_hit_s": self._ftl_hit.percentiles(),
            "ttft_miss_s": self._ftl_miss.percentiles(),
            "hit_requests": self._ftl_hit.count,
            "miss_requests": self._ftl_miss.count,
            "preempt": {
                "enabled": self.preempt,
                "fallback": self.preempt_fallback,
                "count": self.scheduler.preemptions,
                "recomputed_tokens": self._recomputed_tokens,
            },
        }
        if self.page_len:
            rep.update(self.kv.prefix_report())
        return rep

    def report(self) -> dict:
        dt = self._wall() if self._t0 is not None else 0.0
        gen = self._gen_tokens
        # streaming aggregates folded in at retire time: identical to
        # the old full-rescan on short traces (the RollingStat reservoir
        # is exact up to its cap), O(history) instead of O(traffic)
        lat = self._lat_stat.percentiles()
        ftl = self._ftl_stat.percentiles()
        # TTFT decomposition: queueing (no slot), prompt ingestion
        # (chunked prefill calls or the legacy teacher-forced walk), and
        # the first real decode step — first_token_s is their sum, no
        # longer conflating prompt-walk time with queueing
        ttft = {
            "queue_s": self._queue_stat.percentiles(),
            "prefill_s": self._prefill_stat.percentiles(),
            "first_decode_s": self._fdec_stat.percentiles(),
        }
        occ = (self._active_slot_steps / (self._steps * self.num_slots)
               if self._steps else 0.0)
        if self.page_len:
            positions = [int(self._pos[s]) for s in self.scheduler.active]
            paging = {"paged": True, "fallback": None,
                      **self.kv.report(positions)}
        else:
            reserved = self.kv.reserved_kv_bytes()
            paging = {"paged": False, "fallback": self.paging_fallback,
                      "reserved_kv_bytes": reserved,
                      "contiguous_kv_bytes": reserved,
                      "reserved_reduction": 1.0}
        return {
            "requests": self._done_count,
            "retained_requests": len(self.requests),
            "generated_tokens": gen,
            "steps": self._steps,
            "wall_s": dt,
            "tok_per_s": gen / dt if dt > 0 else float("nan"),
            "latency_s": lat,
            "first_token_s": ftl,
            "ttft": ttft,
            "prefill": self.prefill_report(),
            "prefix_reuse": self.prefix_reuse_report(),
            "slot_occupancy": occ,
            "weight_sparsity": self.weight_sparsity,
            "head_compression": self.head_compression,
            "head_fallback": self.head_fallback,
            "weight_stream": self.weight_stream_report(),
            "paging": paging,
            "cache_resets": self.kv.resets,
        }
