"""Continuous-batching serving engine over the sparse decode stack.

The engine turns the straight-line ``serve()`` loop into a serving
system:

* a request queue with per-request prompt / generation-budget state
  (``repro.serve.request``);
* a slot scheduler that admits new requests into freed batch slots
  mid-flight — no drain barrier, decode keeps running at full batch
  width under a stream of arrivals (``repro.serve.scheduler``);
* a slotted KV-cache manager that reuses one donated ``init_cache``
  allocation across request lifetimes (``repro.serve.cache``) — or, with
  ``paged=True``, a paged KV cache (``repro.serve.paging``): fixed-size
  pages allocated lazily off a free list and gathered through per-slot
  page tables, so reserved cache bytes scale with live tokens instead of
  ``num_slots × max_len`` and out-of-pages admission queues instead of
  crashing;
* a chunked prefill subsystem (``repro.serve.prefill``): admitted
  prompts are ingested ``prefill_chunk`` tokens at a time through one
  batched ``build_prefill_step`` call per engine step — chunks from
  every mid-prefill request ride one padded ``(B, C)`` call, every
  projection dispatches at M = C through the packed weight stream, and
  decode keeps running between calls — instead of teacher-forcing each
  prompt through the decode step one position per step
  (``prefill_chunk=0`` keeps that legacy walk as the equivalence
  oracle);
* weights pruned once (``global_l1_prune``) and the *whole serve-time
  stack* packed once into the paper's ``BitmapWeight`` format
  (``repro.serve.packed.pack_model``): attention q/k/v/o, MLP
  gate/up/down, the MoE router + expert stacks, the mamba/rwkv mixer
  and channel-mix projections, and the LM head all dispatch through
  ``kernels/ops.bitmap_spmm`` (per-expert: ``bitmap_spmm_grouped``)
  every decode step — the bitmap-compressed HBM path runs end-to-end at
  serve time, and the per-tensor manifest records what packed vs fell
  back (and why).  DESIGN_PACKED.md documents the subsystem.

Positions are per-slot: the decode step takes a (B,) position vector so
each slot advances through its own sequence independently (the models
layer grew vector-position support for exactly this).

**Request lifecycle + failure semantics** (DESIGN_SERVING.md §Failure
semantics): every request ends in exactly one terminal state — DONE,
CANCELLED (``engine.cancel(rid)``, valid queued / mid-prefill /
mid-decode / mid-preempt-replay), EXPIRED (``deadline_ms`` elapsed), or
SHED (admission control under overload raises/records the typed
``ServeOverloaded``).  A request preempted ``max_preempts`` times is
*pinned*: it re-admits with a worst-case (reserved-page) commitment and
is excluded from victim selection, so recompute-on-preempt can never
livelock one request.  ``audit=True`` runs the step-level invariant
auditor (``repro.serve.faults.InvariantAuditor``) and turns on packed-
tensor integrity scanning: a corrupted tensor (seeded ``FaultPlan``
bitflips, NaN-poisoned heads, or real bit-rot) is quarantined to its
dense fallback with a recorded manifest reason and the engine replays
the affected step deterministically instead of serving garbage.
"""
from __future__ import annotations

import math
import os
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_elastic_mesh
from repro.launch.steps import (build_prefill_step, build_prefill_step_spmd,
                                build_serve_step, build_serve_step_spmd)
from repro.models.config import ModelConfig
from repro.models.model import init_params, lm_head_weight
from repro.serve.cache import SlotKVCache
from repro.serve.errors import (DeadlineExceeded, RequestRejected,
                                ServeOverloaded)
from repro.serve.faults import FaultPlan, InvariantAuditor
from repro.serve.packed import PackedModel, choose_block, pack_model
from repro.serve.paging import OutOfPages, PagedKVCache
from repro.serve.prefill import PrefillPlanner
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import SlotScheduler
from repro.serve.telemetry import Clock, MetricsRegistry, Telemetry
from repro.serve.traffic import TrafficLedger
from repro.sparse.format import BitmapWeight, pack_bitmap, shard_bitmap
from repro.sparse.pruning import global_l1_prune, per_tensor_prune, \
    sparsity_of


def _head_block(d_model: int, vocab: int,
                cap: int = 128) -> Optional[Tuple[int, int]]:
    """Largest (BK, BN) bitmap tile that divides the head; BN % 8 == 0."""
    return choose_block(d_model, vocab, cap)


def pack_lm_head(params, cfg: ModelConfig, sparsity: float = 0.0,
                 cache_dense: bool = False,
                 shards: int = 1) -> Optional[BitmapWeight]:
    """Prune (per-tensor) + pack the (D, V) LM head once for serving.

    ``shards > 1`` asks for the vocab-split (column-parallel) sharded
    layout: the head packs against a tile of the per-shard ``(D, V/S)``
    slice and ``shard_bitmap`` splits the tile axes, so each model-axis
    device stores 1/S of the packed head.  Falls back to the replicated
    pack (``shard=None`` — the caller records the typed reason) when the
    vocab doesn't divide or no per-shard tile fits."""
    block = _head_block(cfg.d_model, cfg.vocab_size)
    if block is None:
        return None
    w = lm_head_weight(params, cfg)
    if sparsity > 0:
        w = per_tensor_prune(w, sparsity)
    wf = np.asarray(w.astype(jnp.float32))
    if shards > 1 and cfg.vocab_size % shards == 0:
        sblock = _head_block(cfg.d_model, cfg.vocab_size // shards)
        if sblock is not None:
            bw = pack_bitmap(wf, block=sblock, cache_dense=cache_dense)
            return shard_bitmap(bw, shards, "col")
    return pack_bitmap(wf, block=block, cache_dense=cache_dense)


class ServeEngine:
    """Continuous-batching decode over ``num_slots`` batch slots."""

    def __init__(self, cfg: ModelConfig, *, num_slots: int = 4,
                 max_len: int = 128, sparsity: float = 0.0, seed: int = 0,
                 model_parallel: int = 1, impl: Optional[str] = None,
                 bitmap_head: bool = True,
                 head_sparsity: Optional[float] = None,
                 stream_weights: bool = True, top_k: int = 0,
                 paged: bool = False, page_len: int = 16,
                 page_pool_tokens: Optional[int] = None,
                 kv_shards: Optional[int] = None,
                 prefill_chunk: int = 0, prefix_reuse: bool = False,
                 preempt: bool = False, history: int = 512,
                 deadline_ms: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 ttft_budget_ms: Optional[float] = None,
                 max_preempts: int = 8, audit: bool = False,
                 faults: Optional[FaultPlan] = None,
                 trace_out: Optional[str] = None,
                 events_out: Optional[str] = None,
                 metrics_out: Optional[str] = None,
                 traffic_out: Optional[str] = None):
        """``head_sparsity``: ``global_l1_prune`` deliberately keeps
        (tied) embeddings dense, so the LM head is additionally pruned
        per-tensor to this level before packing — that is what gives the
        bitmap head its compression at serve time.  Defaults to
        ``sparsity``; pass 0.0 to stream the exact dense head through the
        bitmap path instead (compression < 1, numerics identical to the
        dense head).

        ``stream_weights``: pack the whole decode stack (attention
        q/k/v/o, MLP gate/up/down, MoE router + expert stacks, SSM
        mixer / channel-mix projections) once via ``pack_model`` and
        stream it bitmap-compressed every step.  Packing is lossless, so
        tokens are identical to dense dispatch at any sparsity; pass
        False for a dense-dispatch baseline.

        ``top_k``: engine-default top-k truncation for sampled requests
        (0 = no truncation); each request may override it via
        ``submit(top_k=...)`` — the jitted sampler then applies a
        per-slot masked top-k (all-default serving keeps the static
        ``lax.top_k`` path; the first override costs one extra jit
        signature, mirroring how sampling itself engages).

        ``paged``: page the attention KV cache (``repro.serve.paging``)
        into ``page_len``-token pages gathered through per-slot page
        tables — reserved cache bytes scale with live tokens instead of
        ``num_slots × max_len``.  ``page_pool_tokens`` bounds each page
        pool (default: worst case, still lazily allocated); when pages
        run out, admission queues until retirements free pages.
        ``paged=False`` (or ``page_len=0``) keeps the contiguous layout.

        ``prefill_chunk``: ingest admitted prompts in batched
        ``prefill_chunk``-token chunks (one ``build_prefill_step`` call
        per engine step, chunks from every mid-prefill request batched
        together) instead of teacher-forcing them through decode steps
        one position at a time.  0 keeps the legacy teacher-forcing walk
        — the equivalence oracle: chunked prefill is token-identical to
        it.  Archs with recurrent mixer state (mamba/rwkv/rwkv_cm) or
        the frames frontend fall back to teacher-forcing with a recorded
        reason.

        ``prefix_reuse``: hash ``page_len``-token prompt blocks and map
        a new request's matching prefix onto already-resident physical
        pages copy-on-write (``repro.serve.paging`` prefix cache) — the
        matched region skips prefill entirely, so TTFT on a hit
        collapses to queue + first-decode.  Requires paging; archs with
        recurrent mixer state or the frames frontend fall back with a
        recorded reason (pages don't capture that state, so skipping
        ingestion would drop it).

        ``preempt``: recompute-on-preempt eviction.  Admission commits
        only the *live* ingest pages instead of the worst case
        (occupancy rises at equal pool size); when the free list runs
        dry mid-flight the engine evicts cached prefixes and then
        preempts the youngest slot — its pages return to the pool and
        the request re-queues at the head of the FIFO with prompt +
        already-generated tokens re-ingested on re-admission.  Sampling
        keys fold the absolute position, so recomputed requests emit
        token-identical streams.  Requires paging; the frames frontend
        falls back (its embeds derive from the global step counter, so
        a recompute would diverge).

        ``history``: retired requests kept for inspection (a bounded
        deque); latency aggregates are folded in at retire time
        (``RollingStat``), so a long-lived engine's memory and
        ``report()`` cost stay O(history), not O(total traffic).

        ``deadline_ms``: default per-request latency budget, measured
        from the moment a request's arrival comes due; requests that
        blow it — queued or mid-flight — expire with a recorded
        ``DeadlineExceeded`` (``submit(deadline_ms=...)`` overrides
        per request; None = no deadline).

        ``max_queue`` / ``ttft_budget_ms``: admission-control load
        shedding.  A request that comes due while more than
        ``max_queue`` requests are already due-and-waiting, or while
        the estimated TTFT (queue drain at the observed step rate)
        exceeds ``ttft_budget_ms``, is shed with a typed
        ``ServeOverloaded`` — raised from ``submit`` for requests due
        immediately, recorded on the request for future arrivals.
        None disables shedding (the pre-hardening behavior: queue
        forever).

        ``max_preempts``: bounded-preemption policy.  A request
        preempted this many times re-admits *pinned* — worst-case page
        commitment (the reserved-page fast path) and excluded from
        victim selection — so it finishes instead of livelocking.

        ``audit``: run the step-level invariant auditor every step
        (scheduler slots, page refcount conservation, free xor
        referenced, table aliasing, request-state legality, finite
        logits) plus packed-tensor integrity scans; corruption is
        quarantined to the dense fallback and the step replayed.

        ``faults``: a seeded ``repro.serve.faults.FaultPlan`` whose
        scheduled faults the engine fires at each step start — the
        chaos harness.  Injected faults are deterministic and (under
        ``audit=True``) recoverable: served tokens stay bit-identical
        to a fault-free run.

        ``trace_out`` / ``events_out`` / ``metrics_out``: telemetry
        artifacts (``repro.serve.telemetry``), written by ``close()``.
        Setting any of them turns step-phase spans on: Chrome
        trace-event JSON with per-step phase + per-request lifecycle
        spans, a structured JSONL event log, and a metrics-registry
        snapshot (JSON, or Prometheus text for ``.prom`` paths).  All
        three default off — telemetry-off serving is bit-identical and
        allocation-free on the hot path (spans and events are plain
        ``is not None`` checks; the metrics registry itself is always
        on, since ``report()`` is rendered from it).
        """
        self.cfg = cfg
        self.metrics = MetricsRegistry()
        self._clock = Clock()
        self._steps = 0
        # telemetry first: init-time fallback warnings below emit into
        # the event log, so spans/events must exist before any
        # _warn_fallback can fire
        self.telemetry: Optional[Telemetry] = None
        if trace_out or events_out or metrics_out:
            self.telemetry = Telemetry(self.metrics, self._clock,
                                       trace_out=trace_out,
                                       events_out=events_out,
                                       metrics_out=metrics_out)
        self.spans = (self.telemetry.spans
                      if self.telemetry is not None else None)
        self.events = (self.telemetry.events
                       if self.telemetry is not None else None)
        self.num_slots = num_slots
        self.max_len = max_len
        self.sparsity = sparsity
        self.mesh = make_elastic_mesh(model_parallel)
        # fallback bookkeeping: every recorded reason lands in
        # ``self.fallbacks`` (mirrored by report()["fallbacks"]) and is
        # warned at most once per (key, reason) per engine instance
        self.fallbacks: Dict[str, str] = {}
        self._warned: set = set()

        params = init_params(jax.random.PRNGKey(seed), cfg)
        if sparsity > 0:
            params = global_l1_prune(params, sparsity)
        self.weight_sparsity = sparsity_of(params) if sparsity > 0 else 0.0
        pspecs = shd.named(self.mesh, shd.param_specs(cfg, self.mesh))
        self.params = jax.device_put(params, pspecs)

        # pack once, cache on the engine: every decode step streams the
        # stack + head through the bitmap-compressed kernels/ops path.
        # On the xla (non-TPU) dispatch the pack also renders the dense
        # oracle view, so serving pays no per-step software decompression.
        from repro.kernels.ops import default_impl
        cache_dense = (impl or default_impl()) == "xla"
        self.stream_fallback: Optional[str] = None
        mp_actual = int(self.mesh.shape.get("model", 1))
        self.model_parallel = mp_actual
        # SPMD serving: any multi-device elastic mesh routes the decode /
        # prefill steps through shard_map (steps.build_serve_step_spmd) —
        # packed BitmapWeight leaves shard their explicit shard axis over
        # the "model" axis (format.shard_bitmap layout), paged KV pools
        # shard their pages axis over "data".  Single device keeps the
        # plain jitted steps, bit-identical to before.
        self._spmd = int(self.mesh.devices.size) > 1
        if not stream_weights:
            self.stream_fallback = "stream_weights=False"
            self.fallbacks["stream"] = self.stream_fallback
        self.packed: Optional[PackedModel] = (
            pack_model(self.params, cache_dense=cache_dense,
                       shards=(mp_actual if self._spmd else 1))
            if stream_weights else None)
        if self._spmd and self.packed is not None:
            # place each sharded leaf's shard axis on its own model-axis
            # device (replicated-fallback leaves broadcast) — the
            # per-device packed-HBM cut the stream report models
            self.packed.blocks = jax.device_put(
                self.packed.blocks,
                shd.named(self.mesh,
                          shd.packed_specs(self.packed.blocks, self.mesh)))
        self.head_sparsity = (sparsity if head_sparsity is None
                              else head_sparsity)
        self.head_fallback: Optional[str] = None
        self.head_shard_fallback: Optional[str] = None
        if bitmap_head:
            self.lm_weight = pack_lm_head(
                self.params, cfg, self.head_sparsity,
                cache_dense=cache_dense,
                shards=(mp_actual if self._spmd else 1))
            if (self._spmd and mp_actual > 1
                    and self.lm_weight is not None
                    and self.lm_weight.shard is None):
                self.head_shard_fallback = (
                    f"shard: vocab={cfg.vocab_size} not divisible by "
                    f"{mp_actual} shards (or no per-shard tile); head "
                    f"stored replicated")
                self._warn_fallback(
                    "head_shard", self.head_shard_fallback,
                    f"bitmap LM head stored replicated: "
                    f"{self.head_shard_fallback}")
            if self._spmd and self.lm_weight is not None:
                self.lm_weight = jax.device_put(
                    self.lm_weight,
                    shd.named(self.mesh,
                              shd.bitmap_specs(self.lm_weight, self.mesh)))
            if self.lm_weight is None:
                self.head_fallback = (
                    f"no (BK, BN) tile divides (d_model={cfg.d_model}, "
                    f"vocab={cfg.vocab_size}) with BN % 8 == 0; "
                    f"head served dense")
                self._warn_fallback(
                    "head", self.head_fallback,
                    f"bitmap LM head fell back to dense: "
                    f"{self.head_fallback}")
        else:
            self.lm_weight = None
            self.head_fallback = "disabled (bitmap_head=False)"
            self.fallbacks["head"] = self.head_fallback
        self.head_compression = (self.lm_weight.compression
                                 if self.lm_weight is not None else 1.0)

        self.scheduler = SlotScheduler(num_slots, history=history)
        # paged KV cache: pages only help when some block caches per-token
        # KV lines — otherwise fall back to contiguous with a reason
        self.paging_fallback: Optional[str] = None
        if not paged:
            page_len = 0
        elif not any(b.mixer == "attn" for b in cfg.pattern):
            page_len = 0
            self.paging_fallback = (
                f"{cfg.name}: no attention blocks — recurrent state is "
                f"O(1)/slot, nothing to page")
        if self.paging_fallback:
            self._warn_fallback(
                "paging", self.paging_fallback,
                f"paged KV cache fell back to contiguous: "
                f"{self.paging_fallback}")
        self.page_len = page_len

        # shared-prefix reuse + preemption both live on the paged cache;
        # each falls back (recorded reason, same idiom as above) when its
        # preconditions don't hold rather than failing the engine
        recurrent = any(b.mixer != "attn" or b.ffn == "rwkv_cm"
                        for b in cfg.pattern)
        self.prefix_fallback: Optional[str] = None
        if prefix_reuse:
            if not page_len:
                self.prefix_fallback = (
                    "paged KV cache disabled (or fell back to "
                    "contiguous); no pages to share")
            elif cfg.frontend == "frames":
                self.prefix_fallback = (
                    f"{cfg.name}: frames frontend derives embeds from "
                    f"the step counter; prompt-token hashing is "
                    f"meaningless")
            elif recurrent:
                self.prefix_fallback = (
                    f"{cfg.name}: recurrent mixer state (mamba/rwkv) is "
                    f"not captured by KV pages; skipping ingestion "
                    f"would drop it")
            if self.prefix_fallback:
                prefix_reuse = False
                self._warn_fallback(
                    "prefix_reuse", self.prefix_fallback,
                    f"shared-prefix reuse fell back: "
                    f"{self.prefix_fallback}")
        self.prefix_reuse = prefix_reuse
        self.preempt_fallback: Optional[str] = None
        if preempt:
            if not page_len:
                self.preempt_fallback = (
                    "paged KV cache disabled (or fell back to "
                    "contiguous); no pages to reclaim")
            elif cfg.frontend == "frames":
                self.preempt_fallback = (
                    f"{cfg.name}: frames embeds fold the global step "
                    f"counter, so a preempted request's recompute would "
                    f"diverge from its first run")
            if self.preempt_fallback:
                preempt = False
                self._warn_fallback(
                    "preempt", self.preempt_fallback,
                    f"recompute-on-preempt fell back: "
                    f"{self.preempt_fallback}")
        self.preempt = preempt

        # data-axis KV sharding: partition the paged pools' page-id
        # ranges (and the slots) across the mesh "data" axis so every
        # slot's pages are device-local — allocation stays host-side,
        # the shard_map step gathers/slices the pools per call.  Auto
        # (kv_shards=None): the data extent whenever it divides the
        # slot count; indivisible shapes record a typed reason and keep
        # the replicated pool instead of crashing.
        self.kv_shard_fallback: Optional[str] = None
        ndata = int(self.mesh.shape.get("data", 1))
        kv_actual = 1
        if page_len and self._spmd and ndata > 1:
            want = ndata if kv_shards is None else int(kv_shards)
            if want > 1 and (num_slots % want == 0 and want <= num_slots
                             and want == ndata):
                kv_actual = want
            elif want > 1:
                self.kv_shard_fallback = (
                    f"shard: kv_shards={want} must equal the mesh data "
                    f"axis ({ndata}) and divide num_slots={num_slots}; "
                    f"page pools stored replicated")
                self._warn_fallback(
                    "kv_shard", self.kv_shard_fallback,
                    f"paged KV pools stored replicated: "
                    f"{self.kv_shard_fallback}")
        self.kv = (PagedKVCache(cfg, num_slots, max_len, page_len,
                                pool_tokens=page_pool_tokens,
                                strict=not preempt, shards=kv_actual)
                   if page_len else SlotKVCache(cfg, num_slots, max_len))
        self._kv_data_pools: Tuple[str, ...] = (
            tuple(self.kv.pools) if page_len and kv_actual > 1 else ())
        self.top_k_default = top_k
        if self._spmd:
            step_fn = build_serve_step_spmd(
                cfg, self.mesh, impl=impl, top_k=top_k,
                data_pools=self._kv_data_pools)
        else:
            step_fn = build_serve_step(cfg, impl=impl, top_k=top_k)
        self._jit_step = jax.jit(step_fn, donate_argnums=(1,))

        # chunked prefill: admitted prompts are ingested prefill_chunk
        # tokens at a time through one batched prefill call per engine
        # step; 0 keeps the legacy teacher-forced prompt walk (the
        # equivalence oracle).  Recurrent mixer state advances one token
        # per step by construction, and the frames frontend derives its
        # embeds from the step counter — both keep teacher-forcing with
        # a recorded reason, like the paging fallbacks above.
        self.prefill_fallback: Optional[str] = None
        if prefill_chunk > 0:
            if cfg.frontend == "frames":
                self.prefill_fallback = (
                    f"{cfg.name}: frames frontend derives per-step embeds "
                    f"from the step counter; nothing to prefill")
            elif any(b.mixer != "attn" or b.ffn == "rwkv_cm"
                     for b in cfg.pattern):
                self.prefill_fallback = (
                    f"{cfg.name}: recurrent mixer state (mamba/rwkv) has "
                    f"no chunked prefill path yet; teacher-forcing kept")
            if self.prefill_fallback:
                prefill_chunk = 0
                self._warn_fallback(
                    "prefill", self.prefill_fallback,
                    f"chunked prefill fell back to teacher-forcing: "
                    f"{self.prefill_fallback}")
        self.prefill_chunk = prefill_chunk
        self.planner: Optional[PrefillPlanner] = (
            PrefillPlanner(num_slots, prefill_chunk)
            if prefill_chunk else None)
        if self._spmd:
            prefill_fn = build_prefill_step_spmd(
                cfg, self.mesh, impl=impl,
                data_pools=self._kv_data_pools)
        else:
            prefill_fn = build_prefill_step(cfg, impl=impl)
        self._jit_prefill = (
            jax.jit(prefill_fn, donate_argnums=(1,))
            if prefill_chunk else None)
        # engine-owned accounting lives in the metrics registry — the
        # report sections below are rendered views over these metrics
        m = self.metrics
        self._c_prefill_steps = m.counter(
            "steps.prefill", help="engine steps that ran a prefill call")
        self._c_decode_steps = m.counter(
            "steps.decode", help="engine steps that ran a decode call")

        self._tok = np.zeros(num_slots, np.int32)
        self._pos = np.zeros(num_slots, np.int32)
        # frames frontend: per-step embeddings come from a jax PRNG key
        # folded with the step counter *inside* the jitted step — the old
        # host-side standard_normal forced a host sync every decode step
        self._embed_key = jax.random.PRNGKey(seed + 0x5eed)
        # per-slot sampling state (greedy slots keep temperature 0).
        # _use_sampling stays False until some request asks for T > 0, so
        # all-greedy serving never pays the categorical/top-k machinery
        # (flipping it later costs one extra jit signature compile).
        self._use_sampling = False
        # the per-slot top-k vector (a full-vocab sort in the sampler)
        # only engages once some request *overrides* the engine default —
        # all-default serving keeps the cheaper static lax.top_k path
        self._use_topk_vec = False
        self._temp = np.zeros(num_slots, np.float32)
        self._topk = np.zeros(num_slots, np.int32)
        self._keys = np.zeros((num_slots, 2), np.uint32)
        self._seed = seed
        self._warm = False
        self._c_slot_steps = m.counter(
            "steps.active_slots",
            help="decoding slot-steps (occupancy numerator)")
        self._next_rid = 0
        # per-slot ingest = prompt + tokens generated before a preemption
        # — the teacher-forcing/prefill source, so a recomputed request
        # replays its own history instead of resampling it
        self._ingest: Dict[int, List[int]] = {}
        self._admit_seq = np.zeros(num_slots, np.int64)  # preempt order
        self._admit_counter = 0
        self._c_recomputed = m.counter(
            "tokens.recomputed",
            help="positions re-ingested after preemption")
        # bounded retained history + streaming aggregates: report() reads
        # these instead of rescanning every request ever submitted (the
        # registry histograms keep the seeded RollingStat reservoirs)
        self.history = history
        self.requests: deque = deque(maxlen=max(1, history))
        self._c_done = m.counter("requests.done",
                                 help="requests retired DONE")
        self._c_gen_tokens = m.counter("tokens.generated",
                                       help="tokens delivered by DONE "
                                            "requests")
        self._h_lat = m.histogram("request.latency_s", seed=1,
                                  help="arrival-due -> last token")
        self._h_ftl = m.histogram("request.first_token_s", seed=2,
                                  help="arrival-due -> first token")
        self._h_queue = m.histogram("request.queue_s", seed=3,
                                    help="arrival-due -> slot granted")
        self._h_prefill = m.histogram("request.prefill_s", seed=4,
                                      help="slot granted -> prompt "
                                           "cache resident")
        self._h_fdec = m.histogram("request.first_decode_s", seed=5,
                                   help="prompt resident -> first token")
        self._h_ftl_hit = m.histogram("request.ttft_hit_s", seed=6,
                                      help="TTFT, prefix-cache hits")
        self._h_ftl_miss = m.histogram("request.ttft_miss_s", seed=7,
                                       help="TTFT, prefix-cache misses")

        # ---- lifecycle hardening: deadlines, shedding, bounded
        # preemption, fault injection + invariant auditing ----
        self.deadline_ms = deadline_ms
        self.max_queue = max_queue
        self.ttft_budget_ms = ttft_budget_ms
        self.max_preempts = max_preempts
        self._has_deadlines = deadline_ms is not None
        self._c_cancelled = m.counter("requests.cancelled")
        self._c_expired = m.counter("requests.expired")
        self._c_shed = m.counter("requests.shed")
        self._c_forced_preempts = m.counter(
            "preempts.forced", help="fault-injected forced preemptions")
        self._c_wasted = m.counter(
            "tokens.wasted", help="tokens generated by aborted requests")
        self._step_wall_ema: Optional[float] = None  # TTFT estimator
        self.quarantined: Dict[str, str] = {}
        self.faults = faults
        self.audit = audit
        # checksums are taken here, before any fault can fire — the
        # auditor's integrity scans compare against this pristine state
        self.auditor: Optional[InvariantAuditor] = (
            InvariantAuditor(self) if audit else None)

        # ---- traffic observatory: the ledger is always on (host-int
        # counters in the registry, like every other subsystem);
        # ``traffic_out`` additionally writes the attribution +
        # compiled-HLO cross-check artifact at close() ----
        self.traffic_out = traffic_out
        self._traffic_written = False
        self.traffic = TrafficLedger(self)

        # ---- telemetry: every subsystem registers into the one
        # registry; spans/events only exist when an output is asked for
        # (telemetry-off keeps the hot path allocation-free) ----
        self.scheduler.register_metrics(m)
        self.kv.register_metrics(m)
        self.traffic.register_metrics(m)
        if self.planner is not None:
            self.planner.register_metrics(m)
        if self.packed is not None:
            self.packed.register_metrics(m)
        if self.faults is not None:
            self.faults.register_metrics(m)
        if self.auditor is not None:
            self.auditor.register_metrics(m)
        m.gauge("steps.total", lambda: self._steps,
                help="engine steps taken (includes idle fast-forward)")
        m.gauge("queue.due_depth", self._due_depth,
                help="waiting requests whose arrival has come due")
        self._register_report_views()

    @classmethod
    def from_arch(cls, arch: str, smoke: bool = True, **kw) -> "ServeEngine":
        cfg = get_smoke_config(arch) if smoke else get_config(arch)
        return cls(cfg, **kw)

    def _warn_fallback(self, key: str, reason: str,
                       message: Optional[str] = None) -> None:
        """Record a fallback reason (mirrored into
        ``report()["fallbacks"]``) and warn it — once per (key, reason)
        per engine instance, never once per request or step."""
        self.fallbacks[key] = reason
        msg = message or f"{key} fell back: {reason}"
        if (key, reason) not in self._warned:
            self._warned.add((key, reason))
            self._emit("fallback", key=key, reason=reason)
            warnings.warn(msg, stacklevel=3)

    # --------------------------------------------------------- telemetry ----

    @property
    def _forced_preempts(self) -> int:
        """Fault-injected forced-preemption count (registry counter)."""
        return self._c_forced_preempts.value

    def _emit(self, kind: str, rid: Optional[int] = None,
              **fields) -> None:
        """Append to the structured event log (no-op when telemetry is
        off — a single ``is None`` check, nothing allocated)."""
        if self.events is not None:
            self.events.emit(kind, t=self._clock.now_or_zero(),
                             step=self._steps, rid=rid, **fields)

    def close(self) -> List[str]:
        """Write the configured telemetry artifacts (``--trace-out`` /
        ``--events-out`` / ``--metrics-out`` / ``--traffic-out``);
        idempotent, returns the paths written.  An artifacts-off engine
        returns []."""
        written: List[str] = []
        if self.traffic_out and not self._traffic_written:
            self._traffic_written = True
            d = os.path.dirname(self.traffic_out)
            if d:
                os.makedirs(d, exist_ok=True)
            self.traffic.write(self.traffic_out)
            written.append(self.traffic_out)
        if self.telemetry is not None:
            written.extend(self.telemetry.close())
        return written

    def _trace_counter(self, name: str, values: Dict[str, int]) -> None:
        """Emit one Chrome-trace counter sample (per-phase HBM byte
        track); no-op without ``--trace-out`` — one ``is None`` check."""
        if self.telemetry is None or self.telemetry.trace is None:
            return
        self.telemetry.trace.counter(name, self._clock.now_or_zero(),
                                     values)

    def _register_report_views(self) -> None:
        """Register ``report()``'s top-level fields and sections as
        registry views, in the legacy key order — ``report()`` is then
        a rendered snapshot of the registry and nothing else.  Field
        names and types are pinned by the schema snapshot test."""
        m = self.metrics
        m.view("requests", lambda: self._c_done.value)
        m.view("retained_requests", lambda: len(self.requests))
        m.view("generated_tokens", lambda: self._c_gen_tokens.value)
        m.view("steps", lambda: self._steps)
        m.view("wall_s", lambda: (self._clock.now()
                                  if self._clock.started else 0.0))

        def tok_per_s():
            dt = self._clock.now() if self._clock.started else 0.0
            gen = self._c_gen_tokens.value
            return gen / dt if dt > 0 else float("nan")

        m.view("tok_per_s", tok_per_s)
        m.view("latency_s", self._h_lat.percentiles)
        m.view("first_token_s", self._h_ftl.percentiles)
        # TTFT decomposition: queueing (no slot), prompt ingestion
        # (chunked prefill calls or the legacy teacher-forced walk),
        # and the first real decode step — first_token_s is their sum
        m.view("ttft", lambda: {
            "queue_s": self._h_queue.percentiles(),
            "prefill_s": self._h_prefill.percentiles(),
            "first_decode_s": self._h_fdec.percentiles(),
        })
        m.view("prefill", self.prefill_report)
        m.view("prefix_reuse", self.prefix_reuse_report)
        m.view("slot_occupancy",
               lambda: (self._c_slot_steps.value
                        / (self._steps * self.num_slots)
                        if self._steps else 0.0))
        m.view("weight_sparsity", lambda: self.weight_sparsity)
        m.view("head_compression", lambda: self.head_compression)
        m.view("head_fallback", lambda: self.head_fallback)
        m.view("weight_stream", self.weight_stream_report)
        m.view("traffic", self.traffic.report)
        m.view("paging", self.paging_report)
        m.view("cache_resets", lambda: self.kv.resets)
        m.view("lifecycle", self.lifecycle_report)
        m.view("fallbacks", lambda: dict(self.fallbacks))

    # ------------------------------------------------------------ intake ----

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               arrival: float = 0.0, temperature: float = 0.0,
               seed: Optional[int] = None,
               top_k: Optional[int] = None,
               deadline_ms: Optional[float] = None) -> Request:
        """``temperature`` > 0 samples this request's tokens with its own
        PRNG stream, seeded by ``seed`` (default: engine seed + rid); 0
        stays greedy.  ``top_k`` truncates *this request's* sampling
        (None: the engine default; 0: no truncation).  ``deadline_ms``
        overrides the engine-default latency budget for this request
        (measured from the moment its arrival comes due).

        Raises ``RequestRejected`` (typed, process keeps serving) when
        the request can never run: empty prompt, a generation budget
        below one token, budget beyond ``max_len``, or — under paging —
        a worst-case page need larger than the whole pool.  Raises
        ``ServeOverloaded`` when the request is due *now* and admission
        control is shedding (``max_queue`` / ``ttft_budget_ms``);
        future arrivals are accepted and re-checked when they come due.
        A merely *busy* engine without shedding configured never
        rejects; the request queues until slots (and pages) free up."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise RequestRejected("empty prompt")
        if max_new_tokens < 1:
            # the engine's done-check runs only after appending a token,
            # so a zero budget would quietly generate one anyway — reject
            # it typed instead of silently over-delivering
            raise RequestRejected(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        need = len(prompt) + max_new_tokens - 1
        if need > self.max_len:
            raise RequestRejected(
                f"prompt {len(prompt)} + {max_new_tokens} new tokens "
                f"exceeds max_len {self.max_len}")
        if self.page_len and not self.kv.possible(need):
            raise RequestRejected(
                f"prompt {len(prompt)} + {max_new_tokens} new tokens needs "
                f"more pages than the whole pool holds "
                f"(page_len={self.page_len}); raise page_pool_tokens")
        if arrival <= self._steps:
            reason = self._overload_reason()
            if reason is not None:
                self._c_shed.inc()
                self._emit("shed", reason=reason, at="submit")
                raise ServeOverloaded(
                    reason, queue_depth=self._due_depth(),
                    est_ttft_s=self.estimated_ttft_s())
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival=arrival,
                      temperature=temperature, seed=seed, top_k=top_k,
                      deadline_ms=(deadline_ms if deadline_ms is not None
                                   else self.deadline_ms))
        if req.deadline_ms is not None:
            self._has_deadlines = True
        if temperature > 0:
            self._use_sampling = True
        if top_k is not None and top_k != self.top_k_default:
            self._use_topk_vec = True
        self._next_rid += 1
        # the scheduler owns the request until retirement; the engine's
        # bounded ``requests`` history only receives it when done (the
        # old append-on-submit list grew with total traffic forever)
        self.scheduler.submit(req)
        self._emit("submit", rid=req.rid, prompt_tokens=len(prompt),
                   max_new_tokens=max_new_tokens, arrival=arrival)
        return req

    # -------------------------------------------------------- lifecycle ----

    def cancel(self, rid: int) -> bool:
        """Cancel a request by rid, valid at every lifecycle stage:
        queued (including mid-preempt-replay requeue), mid-prefill, or
        mid-decode.  Pages and prefix-cache references are released
        exactly; partial tokens are kept on the request (state
        CANCELLED, no error — the client asked).  Returns False for an
        unknown or already-terminal rid."""
        for r in self.scheduler.waiting:
            if r.rid == rid:
                self.scheduler.cancel_waiting(r)
                r.transition(RequestState.CANCELLED)
                self._abort(r, RequestState.CANCELLED)
                return True
        for slot, r in list(self.scheduler.active.items()):
            if r.rid == rid:
                req = self._release_slot(slot, RequestState.CANCELLED)
                self._abort(req, RequestState.CANCELLED)
                return True
        return False

    def _release_slot(self, slot: int, state: RequestState) -> Request:
        """Tear a slot down into any terminal state through one path —
        planner job, pages, ingest history, and sampling lanes are all
        released, so no abort route can leak."""
        if self.planner is not None:
            self.planner.cancel(slot)
        req = self.scheduler.release(slot, state=state)
        if self.page_len:
            self.kv.retire(slot)
        self._ingest.pop(slot, None)
        self._pos[slot] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        return req

    def _abort(self, req: Request, state: RequestState,
               error: Optional[Exception] = None) -> None:
        """Terminal bookkeeping for the non-DONE outcomes."""
        req.error = error
        req.done_step = self._steps
        if self._clock.started:
            req.t_done = self._wall()
        if state is RequestState.CANCELLED:
            self._c_cancelled.inc()
        elif state is RequestState.EXPIRED:
            self._c_expired.inc()
        elif state is RequestState.SHED:
            self._c_shed.inc()
        self._c_wasted.inc(len(req.tokens))
        self.requests.append(req)
        self._emit(state.name.lower(), rid=req.rid,
                   tokens=len(req.tokens),
                   reason=str(error) if error is not None else None)
        if self.telemetry is not None:
            self.telemetry.request_done(req)

    def _due_depth(self) -> int:
        """Waiting requests whose arrival has come due."""
        return sum(1 for r in self.scheduler.waiting
                   if r.arrival <= self._steps)

    def estimated_ttft_s(self) -> Optional[float]:
        """Deterministic queue-drain TTFT estimate for a request
        arriving now: outstanding work tokens (due queue + live
        remainder) spread over the slots, at the observed per-step wall
        EMA.  None until the first step has been timed."""
        if self._step_wall_ema is None:
            return None
        work = 0
        for r in self.scheduler.waiting:
            if r.arrival <= self._steps:
                work += len(r.prompt) + r.max_new_tokens - 1
        for slot, r in self.scheduler.active.items():
            total = len(r.prompt) + r.max_new_tokens - 1
            work += max(0, total - int(self._pos[slot]))
        return (work / self.num_slots) * self._step_wall_ema

    def _overload_reason(self, exclude_self: bool = False) -> Optional[str]:
        """Shed reason if admission control refuses a request due now.

        ``exclude_self``: the step-start sweep evaluates a request that
        already sits in the waiting queue, so it must not count toward
        its own queue depth (a lone request on an idle engine is never
        "overload")."""
        depth = self._due_depth() - (1 if exclude_self else 0)
        if self.max_queue is not None and depth >= self.max_queue:
            return f"queue depth {depth} >= max_queue {self.max_queue}"
        if self.ttft_budget_ms is not None:
            est = self.estimated_ttft_s()
            if est is not None and est * 1e3 > self.ttft_budget_ms:
                return (f"estimated TTFT {est * 1e3:.1f}ms > budget "
                        f"{self.ttft_budget_ms:.1f}ms")
        return None

    def _deadline_passed(self, req: Request, wall: float) -> bool:
        return (req.deadline_ms is not None and req.t_due is not None
                and (wall - req.t_due) * 1e3 > req.deadline_ms)

    def _pinned(self, slot: int) -> bool:
        """A slot whose request exhausted its preemption budget: it
        holds a worst-case (reserved) page commitment and is excluded
        from victim selection — the reserved-page fast path that lets
        an over-preempted request finish instead of livelocking."""
        req = self.scheduler.active.get(slot)
        return (req is not None
                and len(req.t_preempt) >= self.max_preempts)

    # ------------------------------------------------------------- loop ----

    def _wall(self) -> float:
        return self._clock.now()

    def _commit_tokens(self, req: Request) -> int:
        """Pages to commit at admission, in tokens.  Strict mode commits
        the worst case (prompt + full budget) so allocation can never
        fail mid-flight; preemptible mode commits only the *live* ingest
        (prompt + tokens already generated before a preemption) — more
        requests fit the same pool, and growth past the commitment is
        covered by recompute-on-preempt.  A request that exhausted its
        ``max_preempts`` budget re-admits with the worst case even in
        preemptible mode: its pages are genuinely reserved, so it can
        run to completion untouched (the pinned fast path)."""
        if self.preempt and len(req.t_preempt) < self.max_preempts:
            return len(req.prompt) + len(req.tokens)
        return len(req.prompt) + req.max_new_tokens - 1

    def _with_pages(self, fn, requester: int):
        """Run a page-allocating call, resolving ``OutOfPages`` (raised
        only in preemptible mode, after the prefix cache has been
        drained) by preempting the youngest slot until it succeeds."""
        while True:
            try:
                return fn()
            except OutOfPages:
                self._reclaim(requester)

    def _reclaim(self, requester: int) -> None:
        # sharded pools: only a same-shard victim's pages can serve the
        # requester (page-id ranges are disjoint across shards)
        d = self.kv.slot_shard(requester)
        victims = [s for s in self.scheduler.active
                   if s != requester and not self._pinned(s)
                   and self.kv.slot_shard(s) == d]
        if not victims and self.kv.restore_held():
            # a fault-injected page squeeze confiscated the headroom and
            # there is no one left to preempt: hand the pages back early
            # rather than deadlocking the pinned/last request
            return
        # unreachable by construction: submit() checks possible(), a
        # lone slot's own pages never exceed its capped worst case, and
        # pinned slots hold worst-case commitments (they never need to
        # steal) — a dry pool always implicates an evictable cache entry
        # (already drained) or a preemptable slot
        assert victims, "page pool exhausted with no preemptable slot"
        victim = max(victims, key=lambda s: int(self._admit_seq[s]))
        self._preempt_slot(victim)

    def _preempt_slot(self, slot: int) -> None:
        """Preempt: reclaim the slot's pages and re-queue its request at
        the head of the FIFO.  Everything computed so far is discarded;
        on re-admission the prompt + already-generated tokens re-ingest
        through the normal prefill path (vLLM-style recompute).  Decode
        sampling keys fold the absolute position, so the recomputed
        stream is token-identical to the undisturbed one."""
        req = self.scheduler.active[slot]
        req.t_preempt.append(self._wall())
        self._emit("preempt", rid=req.rid, slot=slot,
                   tokens=len(req.tokens))
        if self.planner is not None:
            self.planner.cancel(slot)
        self.scheduler.requeue(slot)
        if self.page_len:
            self.kv.retire(slot)
        self._ingest.pop(slot, None)
        self._pos[slot] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0

    def _retire(self, req: Request) -> None:
        """Fold the finished request into the streaming aggregates and
        the bounded retained history — report() never rescans."""
        self._c_done.inc()
        self._c_gen_tokens.inc(len(req.tokens))
        self._h_lat.observe(req.latency_s)
        self._h_ftl.observe(req.first_token_s)
        self._h_queue.observe(req.queue_s)
        self._h_prefill.observe(req.prefill_s)
        self._h_fdec.observe(req.first_decode_s)
        (self._h_ftl_hit if req.prefix_hit_tokens > 0
         else self._h_ftl_miss).observe(req.first_token_s)
        self.requests.append(req)
        self._emit("done", rid=req.rid, tokens=len(req.tokens),
                   latency_s=req.latency_s)
        if self.telemetry is not None:
            self.telemetry.request_done(req)

    def _recover_corruption(self, logits, decoding: List[int]) -> bool:
        """Integrity scan + quarantine + deterministic replay (the
        ``audit=True`` corruption path).  Returns True when corruption
        was found — the caller then discards the step's results.

        Detection: every packed tensor (stack leaves + LM head) is
        checksummed against its pack-time CRC and scanned for
        non-finite values.  Recovery: each corrupted tensor is
        *quarantined* — its packed leaf becomes None so
        ``matmul_or_bitmap`` dispatches the pristine dense params
        tensor, with the reason recorded in the manifest — then the
        prefix cache is flushed (published pages may hold KV lines
        written through the corrupt path) and every active slot is
        preempted, so all in-flight requests replay through the clean
        path.  Packing is lossless and replay deterministic, so the
        recovered stream is bit-identical to a never-faulted run.
        Non-finite logits with *no* attributable tensor raise
        ``AuditViolation`` instead — that is a bug, not a recoverable
        fault."""
        bad = self.auditor.integrity_scan()
        if not bad:
            if logits is not None:
                self.auditor.check_logits(np.asarray(logits), decoding)
            return False
        for path in bad:
            reason = ("quarantined: integrity checksum mismatch "
                      "(served dense from pristine params)")
            if path == "lm_head":
                self.lm_weight = None
                self.head_fallback = reason
                self.head_compression = 1.0
                self._warn_fallback(
                    "head", reason,
                    f"bitmap LM head quarantined to dense: corrupted "
                    f"value/bitmap payload detected")
            else:
                self.packed.quarantine(path, reason)
                self._warn_fallback(
                    f"quarantine:{path}", reason,
                    f"packed tensor {path} quarantined to dense: "
                    f"corrupted value/bitmap payload detected")
            self.quarantined[path] = reason
            self.auditor.drop(path)
            self._emit("quarantine", tensor=path, reason=reason)
        # a quarantine flips manifest entries to dense — the traffic
        # ledger's cached role rows are stale now
        self.traffic.invalidate()
        if self.page_len:
            self.kv.flush_prefix()
        for slot in list(self.scheduler.active):
            self._preempt_slot(slot)
        return True

    def _decode(self, tok: jnp.ndarray, pos: jnp.ndarray):
        packed = self.packed.blocks if self.packed is not None else None
        kw = dict(lm_weight=self.lm_weight, packed=packed)
        if self.page_len:
            kw["page_tables"] = self.kv.tables()
        if self._use_sampling:
            kw.update(sample_keys=jnp.asarray(self._keys),
                      temperature=jnp.asarray(self._temp))
            if self._use_topk_vec:
                kw["top_ks"] = jnp.asarray(self._topk)
        if self.cfg.frontend == "frames":
            # device-side frame embeddings: fold the step counter into a
            # carried key — no host RNG (and no host sync) in the hot loop
            ekey = jax.random.fold_in(self._embed_key, self._steps)
            return self._jit_step(self.params, self.kv.cache, None, pos,
                                  embed_rng=ekey, **kw)
        return self._jit_step(self.params, self.kv.cache, tok, pos, **kw)

    def _prefill(self, tokens: np.ndarray, pos: np.ndarray,
                 lens: np.ndarray):
        """One jitted chunked-prefill call over the fixed (B, C) batch."""
        packed = self.packed.blocks if self.packed is not None else None
        kw = dict(packed=packed)
        if self.page_len:
            kw["page_tables"] = self.kv.tables()
        return self._jit_prefill(self.params, self.kv.cache,
                                 jnp.asarray(tokens), jnp.asarray(pos),
                                 jnp.asarray(lens), **kw)

    def _prefill_call(self) -> None:
        """Run the planner's next batched chunk call and route results.

        Under paging, every participating slot's chunk pages are
        bulk-mapped in one admission (``ensure_range``) before the call.
        Slots that finish their last chunk here flip to decode phase at
        position ``len(prompt) - 1`` — the next decode step consumes the
        final prompt token and samples the first generated token, just
        like the teacher-forcing path's last prompt step did.
        """
        tokens, pos, lens, finished = self.planner.next_call()
        if self.page_len:
            # oldest slots first: if mapping runs the pool dry in
            # preemptible mode, the youngest victims haven't mapped yet —
            # their reclaimed pages go to the older requesters (a
            # preempted slot's lane still scatters, into the trash page)
            order = sorted(np.nonzero(lens)[0],
                           key=lambda s: int(self._admit_seq[int(s)]))
            for slot in order:
                if int(slot) not in self.scheduler.active:
                    continue
                self._with_pages(
                    lambda s=int(slot): self.kv.ensure_range(
                        s, int(pos[s]), int(pos[s]) + int(lens[s])),
                    int(slot))
        hidden, cache = self._prefill(tokens, pos, lens)
        self.kv.cache = cache
        self._trace_counter("hbm.prefill",
                            self.traffic.on_prefill(pos, lens))
        jax.block_until_ready(hidden)
        wall = self._wall()
        if self.prefix_reuse:
            # publish each advanced slot's fully-written blocks *now* —
            # before any later chunk can ring-wrap over them
            for slot in np.nonzero(lens)[0]:
                if int(slot) in self.scheduler.active:
                    self.kv.register_prefix(
                        int(slot), self._ingest[int(slot)],
                        int(pos[slot]) + int(lens[slot]))
        for slot in finished:
            if slot not in self.scheduler.active:
                continue               # preempted mid-call
            req = self.scheduler.active[slot]
            ing = self._ingest[slot]
            self._pos[slot] = len(ing) - 1
            self._tok[slot] = ing[-1]
            if req.t_prefill_done is None:
                req.t_prefill_done = wall
                self._emit("prefill_done", rid=req.rid, slot=slot)
        for slot in np.nonzero(lens)[0]:
            if self.planner.in_prefill(int(slot)):
                # park the passenger's decode write on the next unwritten
                # prompt position: the next chunk rewrites that line
                # before anything reads it
                self._pos[slot] = self.planner.next_pos(int(slot))
        self._c_prefill_steps.inc()

    def warmup(self) -> None:
        """Compile the decode step + slot reset before the latency clock
        starts — otherwise the first request's percentiles measure XLA
        compile time, not serving.  Slots are all idle here; whatever the
        throwaway steps write at position 0 is zeroed again on admission.

        Two throwaway decodes, not one: the first consumes the freshly
        allocated (uncommitted) cache, but its *output* cache carries the
        mesh's NamedSharding, which is a different jit signature — a
        single-step warmup left the steady-state executable to compile
        inside the first timed step (≈0.8 s mid-run for the packed
        stack).  The second call compiles the steady-state signature.
        """
        if self._warm:
            return
        for _ in range(2):
            nxt, _, cache = self._decode(jnp.asarray(self._tok[:, None]),
                                         jnp.asarray(self._pos))
            self.kv.cache = cache
        jax.block_until_ready(nxt)
        if self.prefill_chunk:
            # compile the prefill signature too: a throwaway call with
            # every lane masked (lens = 0) writes nothing — contiguous
            # lanes drop out of the scatter, paged lanes hit the trash
            # page — so the cache the first real step sees is untouched.
            # It runs after the decode warmup, so it consumes (and
            # yields) the steady-state committed-sharding cache.
            hidden, cache = self._prefill(
                np.zeros((self.num_slots, self.prefill_chunk), np.int32),
                np.zeros(self.num_slots, np.int32),
                np.zeros(self.num_slots, np.int32))
            self.kv.cache = cache
            jax.block_until_ready(hidden)
        self.kv.warmup()
        self._warm = True

    def step(self) -> None:
        """One engine step: admit, at most one batched prefill call, then
        the full-batch decode step (skipped only when every active slot
        is mid-prefill).

        With telemetry on, every host-side stretch of this method sits
        inside exactly one phase span (``telemetry.PHASES``): schedule →
        [prefill] → [page_ensure → decode → host_sync → sample] →
        [deadline_sweep] → [audit].  Spans bracket host code only — the
        decode phase ends at dispatch, and device time surfaces in
        ``host_sync`` (the existing block-until-ready point) — so the
        per-step phase sum accounts for the step wall without adding
        transfers or syncs.  Telemetry off: ``sp is None`` and every
        bracket is a dead branch."""
        self.warmup()
        # the serving clock starts *after* warmup — one idempotent
        # helper (telemetry.Clock), so no call path can leak compile
        # time into the first timed step
        self._clock.start()
        sp = self.spans
        t_begin = time.perf_counter()
        if sp is not None:
            sp.step_begin(self._steps, t_begin)
            sp.begin("schedule")
        now = float(self._steps)
        if self.faults is not None:
            n_log = len(self.faults.log)
            self.faults.fire(self, self._steps)
            if self.events is not None:
                for entry in list(self.faults.log)[n_log:]:
                    self._emit("fault", kind_detail=entry.get("kind"),
                               fired=bool(entry.get("fired")),
                               tensor=entry.get("tensor"))
        shedding = (self.max_queue is not None
                    or self.ttft_budget_ms is not None)
        for r in list(self.scheduler.waiting):
            if r.arrival <= now and r.t_due is None:
                r.t_due = self._wall()
                if shedding:
                    reason = self._overload_reason(exclude_self=True)
                    if reason is not None:
                        # came due while overloaded: shed silently with
                        # the typed error recorded (submit already
                        # raised for requests due at submission time)
                        self.scheduler.cancel_waiting(r)
                        r.transition(RequestState.SHED)
                        self._abort(r, RequestState.SHED,
                                    error=ServeOverloaded(
                                        reason,
                                        queue_depth=self._due_depth()))
                        continue
            if self._has_deadlines and self._deadline_passed(
                    r, self._wall()):
                self.scheduler.cancel_waiting(r)
                r.transition(RequestState.EXPIRED)
                self._abort(r, RequestState.EXPIRED,
                            error=DeadlineExceeded(
                                f"rid {r.rid}: queued past its "
                                f"{r.deadline_ms:.0f}ms deadline"))
        fits = None
        if self.page_len:
            # out-of-pages: the head-of-line request queues (strict FIFO)
            # until retirements free enough pages — never a crash.  The
            # gate *reserves* (check-and-commit), so multiple admissions
            # in one pass can't over-commit the pool.
            # the reservation lands in the candidate slot's shard — admit
            # evaluates fits *before* popping the slot, so free[0] is the
            # slot this request will get (sharded pools commit per shard;
            # unsharded pools ignore the slot)
            fits = lambda r: self.kv.reserve(
                self._commit_tokens(r),
                slot=(self.scheduler.free[0] if self.scheduler.free else 0))
        for slot, req in self.scheduler.admit(now, fits=fits):
            # ingest = prompt plus tokens generated before a preemption:
            # a recomputed request teacher-forces/prefills its own
            # history instead of resampling it
            ing = list(req.prompt) + list(req.tokens)
            self._admit_seq[slot] = self._admit_counter
            self._admit_counter += 1
            shared = 0
            if self.page_len:
                blocks = None
                if self.prefix_reuse:
                    _, blocks = self.kv.match_prefix(ing, slot=slot)
                shared = self.kv.admit(slot, self._commit_tokens(req),
                                       prefix=blocks)
            else:
                self.kv.reset_slot(slot)
            self._ingest[slot] = ing
            if not req.t_preempt:
                req.prefix_hit_tokens = shared
            else:
                # recompute cost actually paid on this re-admission
                # (adopted blocks — often this request's own earlier
                # registrations — shrink it)
                req.recomputed_tokens += max(0, len(ing) - 1 - shared)
                self._c_recomputed.inc(max(0, len(ing) - 1 - shared))
            self._pos[slot] = shared
            self._tok[slot] = ing[shared]
            self._temp[slot] = req.temperature
            self._topk[slot] = (req.top_k if req.top_k is not None
                                else self.top_k_default)
            rseed = req.seed if req.seed is not None \
                else self._seed + 0x9e37 * (req.rid + 1)
            self._keys[slot] = np.asarray(jax.random.PRNGKey(rseed))
            req.admit_step = self._steps
            if req.t_due is None:
                req.t_due = self._wall()
            if req.t_admit is None:   # re-admissions keep the first mark
                req.t_admit = self._wall()
            if self.planner is not None:
                self.planner.start(slot, ing, start=shared)
            if shared >= len(ing) - 1 and req.t_prefill_done is None:
                # nothing left to ingest — single-token prompt, or a full
                # prefix hit: TTFT collapses to queue + first-decode
                req.t_prefill_done = req.t_admit
            self._emit("admit", rid=req.rid, slot=slot,
                       prefix_hit_tokens=shared)
        if sp is not None:
            sp.end()

        # at most one prefill call per engine step: a stream of long
        # prompts interleaves chunk calls with decode steps instead of
        # starving the decoding slots
        prefilled = False
        if self.planner is not None and self.planner.has_work:
            if sp is not None:
                sp.begin("prefill")
            self._prefill_call()
            if sp is not None:
                sp.end()
            prefilled = True

        in_prefill = (self.planner.in_prefill if self.planner is not None
                      else lambda s: False)
        decoding = [s for s in self.scheduler.active if not in_prefill(s)]
        if decoding or not prefilled:
            if self.page_len:
                # map each decoding slot's current write page; mid-prefill
                # passengers stay unmapped and scribble into the trash
                # page (or an unwritten line their next chunk rewrites).
                # Oldest first: in preemptible mode a dry pool preempts
                # the youngest slots, which haven't mapped yet
                if sp is not None:
                    sp.begin("page_ensure")
                for slot in sorted(decoding,
                                   key=lambda s: int(self._admit_seq[s])):
                    if slot not in self.scheduler.active:
                        continue
                    self._with_pages(
                        lambda s=slot: self.kv.ensure(
                            s, int(self._pos[s])), slot)
                decoding = [s for s in self.scheduler.active
                            if not in_prefill(s)]
                if sp is not None:
                    sp.end()
            self._trace_counter("hbm.decode", self.traffic.on_decode(
                [int(self._pos[s]) for s in decoding]))
            if sp is not None:
                sp.begin("decode")
            nxt, logits, cache = self._decode(
                jnp.asarray(self._tok[:, None]), jnp.asarray(self._pos))
            self.kv.cache = cache
            if sp is not None:
                sp.end()
                sp.begin("host_sync")
            nxt_host = np.asarray(nxt)
            if sp is not None:
                sp.end()
            wall = self._wall()

            if sp is not None:
                sp.begin("sample")
            if self.audit and self._recover_corruption(logits, decoding):
                # a corrupted tensor was quarantined and every active
                # slot preempted: nothing from this step is committed —
                # the requests replay deterministically through the now-
                # clean (dense-fallback) path, emitting the exact tokens
                # the uncorrupted step would have
                pass
            else:
                self._c_slot_steps.inc(len(decoding))
                for slot, req in list(self.scheduler.active.items()):
                    if in_prefill(slot):
                        continue
                    ing = self._ingest[slot]
                    p = int(self._pos[slot])
                    self._pos[slot] = p + 1
                    if (self.prefix_reuse
                            and (p + 1) % self.page_len == 0):
                        # a block boundary just filled: publish it
                        # (prompt *and* generated blocks — identical
                        # greedy requests reuse each other's
                        # generations too)
                        self.kv.register_prefix(slot, ing, p + 1)
                    if p + 1 < len(ing):
                        # still consuming prompt/recompute history:
                        # teacher-force the next token (legacy walk, or
                        # a preempted request replaying its generated
                        # prefix)
                        self._tok[slot] = ing[p + 1]
                        if (p + 1 == len(ing) - 1
                                and req.t_prefill_done is None):
                            req.t_prefill_done = wall  # cache resident
                            self._emit("prefill_done", rid=req.rid,
                                       slot=slot)
                        continue
                    t = int(nxt_host[slot])
                    req.tokens.append(t)
                    ing.append(t)
                    if req.t_first is None:
                        req.t_first = wall
                        if self.events is not None:
                            self._emit("first_token", rid=req.rid,
                                       slot=slot)
                    self._tok[slot] = t
                    if (len(req.tokens) >= req.max_new_tokens
                            or p + 1 >= self.max_len):
                        req.t_done = wall
                        req.done_step = self._steps
                        self._release_slot(slot, RequestState.DONE)
                        self._retire(req)
            if sp is not None:
                sp.end()
            self._c_decode_steps.inc()
        elif self.audit:
            # prefill-only step: no logits to check, but a fault may
            # have corrupted tensors the prefill call just consumed
            if sp is not None:
                sp.begin("audit")
            self._recover_corruption(None, [])
            if sp is not None:
                sp.end()
        if self._has_deadlines:
            if sp is not None:
                sp.begin("deadline_sweep")
            wall = self._wall()
            for slot in list(self.scheduler.active):
                req = self.scheduler.active[slot]
                if self._deadline_passed(req, wall):
                    self._release_slot(slot, RequestState.EXPIRED)
                    self._abort(req, RequestState.EXPIRED,
                                error=DeadlineExceeded(
                                    f"rid {req.rid}: exceeded its "
                                    f"{req.deadline_ms:.0f}ms deadline "
                                    f"mid-flight"))
            if sp is not None:
                sp.end()
        if self.auditor is not None:
            if sp is not None:
                sp.begin("audit")
            try:
                self.auditor.check_step()
            except Exception as e:
                self._emit("audit_violation", reason=str(e))
                raise
            if sp is not None:
                sp.end()
        dt = time.perf_counter() - t_begin
        if sp is not None:
            sp.step_end()
        self._step_wall_ema = (dt if self._step_wall_ema is None
                               else 0.8 * self._step_wall_ema + 0.2 * dt)
        self._steps += 1

    def run(self) -> dict:
        """Drive until every submitted request has drained; report stats."""
        self.warmup()
        self._clock.start()
        while self.scheduler.has_work:
            if not self.scheduler.active:
                # idle: fast-forward the step clock to the next arrival
                nxt = self.scheduler.next_arrival()
                if nxt > self._steps:
                    self._steps = int(math.ceil(nxt))
            self.step()
        return self.report()

    # ---------------------------------------------------------- reports ----

    def weight_stream_report(self) -> dict:
        """Modeled per-step weight-HBM bytes, sparse vs dense, aggregated
        across the whole decode stack (blocks + LM head).

        Embeddings are excluded: the token lookup gathers B rows, it does
        not stream the table.  The head term is the packed head's bitmap
        bytes, or its dense bytes when the head fell back.

        MoE expert stacks count once per *activated* expert per step —
        with ``num_slots`` slots each routing to ``top_k`` experts, a
        decode step touches at most ``min(E, num_slots × top_k)`` experts
        — not once per stored expert (accounting rule in
        DESIGN_PACKED.md §traffic model).
        """
        head_dense = (self.cfg.d_model * self.cfg.vocab_size
                      * np.dtype(np.float32).itemsize)
        head_sparse = (self.lm_weight.hbm_bytes
                       if self.lm_weight is not None else head_dense)
        head_sh = (self.lm_weight.shard[1]
                   if self.lm_weight is not None
                   and self.lm_weight.shard is not None else 1)
        activated = (self.num_slots * self.cfg.top_k
                     if self.cfg.num_experts else None)
        if self.packed is not None:
            rep = self.packed.stream_report(activated_experts=activated)
        else:
            # dense-dispatch baseline: same accounting rule, same code —
            # router-gated expert stacks stream once per activated expert
            from repro.serve.packed import ROUTED_EXPERT, activated_scale
            dense = 0
            for bdict in self.params["blocks"].values():
                for comp, tensors in bdict.items():
                    for name, leaf in tensors.items():
                        b = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                        routed = (leaf.shape[1]
                                  if (comp, name) in ROUTED_EXPERT
                                  and leaf.ndim == 4 else 0)
                        dense += int(round(
                            b * activated_scale(routed, activated)))
            rep = {"sparse_bytes_per_step": dense,
                   "dense_bytes_per_step": dense, "reduction": 1.0,
                   "packed_tensors": 0, "fallback_tensors": 0,
                   "activated_experts": activated,
                   "fallbacks": {"*": self.stream_fallback
                                 or "stream_weights=False"},
                   "shards": 1,
                   "device_sparse_bytes_per_step": dense,
                   "device_dense_bytes_per_step": dense,
                   "shard_fallbacks": {}}
        sparse = rep["sparse_bytes_per_step"] + head_sparse
        dense = rep["dense_bytes_per_step"] + head_dense
        # per-device terms: a sharded head streams 1/S of its packed
        # bytes per model-axis device; the dense head (and a replicated
        # packed head) is resident — and streamed — whole on every device
        dev_sparse = (rep["device_sparse_bytes_per_step"]
                      + head_sparse // head_sh)
        dev_dense = rep["device_dense_bytes_per_step"] + head_dense
        shard_fb = dict(rep.get("shard_fallbacks", {}))
        if self.head_shard_fallback:
            shard_fb["lm_head"] = self.head_shard_fallback
        return {**rep,
                "sparse_bytes_per_step": sparse,
                "dense_bytes_per_step": dense,
                "reduction": dense / sparse if sparse else 1.0,
                "device_sparse_bytes_per_step": dev_sparse,
                "device_dense_bytes_per_step": dev_dense,
                "shard_fallbacks": shard_fb}

    def prefill_report(self) -> dict:
        """The prefill section: chunk-call accounting + the step split."""
        rep = {"enabled": self.prefill_chunk > 0,
               "fallback": self.prefill_fallback,
               "prefill_steps": self._c_prefill_steps.value,
               "decode_steps": self._c_decode_steps.value}
        if self.planner is not None:
            rep.update(self.planner.report())
        else:
            rep.update({"chunk": 0, "calls": 0, "tokens_prefilled": 0,
                        "in_flight": 0, "lane_utilization": None})
        return rep

    def prefix_reuse_report(self) -> dict:
        """Shared-prefix + preemption stats: cache hit/evict/fork
        counters (from the paged cache), the hit-vs-miss TTFT split, and
        the preemption/recompute accounting."""
        rep = {
            "enabled": self.prefix_reuse,
            "fallback": self.prefix_fallback,
            "ttft_hit_s": self._h_ftl_hit.percentiles(),
            "ttft_miss_s": self._h_ftl_miss.percentiles(),
            "hit_requests": self._h_ftl_hit.count,
            "miss_requests": self._h_ftl_miss.count,
            "preempt": {
                "enabled": self.preempt,
                "fallback": self.preempt_fallback,
                "count": self.scheduler.preemptions,
                "recomputed_tokens": self._c_recomputed.value,
            },
        }
        if self.page_len:
            rep.update(self.kv.prefix_report())
        return rep

    def lifecycle_report(self) -> dict:
        """Terminal-state taxonomy + overload/fault accounting.

        Every request the engine has ever retired lands in exactly one
        terminal state (DONE / CANCELLED / EXPIRED / SHED); the counts
        here partition ``requests + retained`` minus what is still
        queued or active.  ``shed`` additionally counts submit-time
        rejections (no Request object is retained for those)."""
        by_state: Dict[str, int] = {}
        for req in self.requests:
            by_state[req.state.name] = by_state.get(req.state.name, 0) + 1
        rep = {
            "deadline_ms": self.deadline_ms,
            "max_queue": self.max_queue,
            "ttft_budget_ms": self.ttft_budget_ms,
            "max_preempts": self.max_preempts,
            "cancelled": self._c_cancelled.value,
            "expired": self._c_expired.value,
            "shed": self._c_shed.value,
            "forced_preempts": self._forced_preempts,
            "wasted_tokens": self._c_wasted.value,
            "estimated_ttft_s": self.estimated_ttft_s(),
            "terminal_states": by_state,
            "quarantined": dict(self.quarantined),
        }
        if self.faults is not None:
            rep["faults"] = self.faults.summary()
        if self.auditor is not None:
            rep["audit"] = self.auditor.report()
        return rep

    def paging_report(self) -> dict:
        """The paging section: pool accounting under paged KV, or the
        contiguous-reservation equivalent when paging fell back."""
        if self.page_len:
            positions = [int(self._pos[s]) for s in self.scheduler.active]
            return {"paged": True, "fallback": None,
                    **self.kv.report(positions)}
        reserved = self.kv.reserved_kv_bytes()
        return {"paged": False, "fallback": self.paging_fallback,
                "reserved_kv_bytes": reserved,
                "contiguous_kv_bytes": reserved,
                "reserved_reduction": 1.0}

    def report(self) -> dict:
        """A rendered snapshot of the metrics registry — every section
        is a registered view, every scalar a registered metric, so the
        same registry also exports Prometheus text and the JSON
        snapshot (``--metrics-out``) without a second bookkeeping
        path.  Key order and field types match the pre-registry
        report() exactly (pinned by the schema snapshot test)."""
        return self.metrics.render()
