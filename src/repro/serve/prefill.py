"""Chunked batched prefill planner for the serving engine.

Until now the engine admitted every request by teacher-forcing its
prompt through the B=1-token decode step, one position per engine step:
a 100-token prompt cost 100 full-batch decode steps of latency before
the first generated token, and every one of those steps streamed the
whole compressed weight stack to advance a single position per slot.
Prefill is exactly where the bitmap weight stream amortizes — EIE and
CoDR both make the case that compressed-weight reuse pays off when many
activations share one fetched weight tile, and decode (M=1) is the
worst case while prefill (M=chunk) is the best.

The planner turns waiting prompts into fixed-shape prefill calls:

* each admitted request's prompt positions ``0 .. len(prompt)-2`` are
  split into fixed ``chunk``-token pieces (the last prompt token is
  *not* prefilled — it feeds the first real decode step, which samples
  the first generated token exactly like the teacher-forcing path did);
* every engine step, chunks from **all** slots currently mid-prefill are
  batched into one padded ``(num_slots, chunk)`` call — one jit
  signature regardless of how many requests are prefilling, with
  padding lanes masked by a per-slot length vector;
* the engine budgets **at most one prefill call per engine step**, so a
  stream of long prompts cannot starve the decode slots: prefill and
  decode interleave step for step, decode keeps running at full batch
  width, and prefilling slots ride the decode batch as masked
  passengers until their cache is resident.

The planner is pure host-side bookkeeping — the device work is
``models.model.prefill_hidden`` via ``launch.steps.build_prefill_step``.

Invariants (equivalence-tested in tests/test_prefill.py and the
full-matrix test in tests/test_packed_streaming.py):

* **Bit-identical to teacher-forcing** — ``prefill_hidden`` writes then
  attends one token at a time inside the chunk, so the cache state (and
  therefore every sampled token) equals the ``prefill_chunk=0`` legacy
  walk at every position, across windows/ring wraps, MoE (chunk folded
  into the batch dim so capacity matches decode), contiguous and paged
  caches, dense and packed weight streams.
* **The last prompt token is never prefilled** — it feeds the first
  real decode step, which samples the first generated token exactly
  like the teacher-forcing path did.
* **One jit signature** — every call is a padded ``(num_slots, chunk)``
  batch with a per-slot length mask; ``lens == 0`` lanes write nothing
  (contiguous lanes drop out of the scatter, paged lanes hit the trash
  page).
* **At most one prefill call per engine step** — decode never starves;
  mid-prefill slots ride the decode batch as masked passengers parked
  on their next unwritten position.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

from repro.serve.errors import AuditViolation


@dataclasses.dataclass
class PrefillJob:
    """One slot's remaining prompt ingestion."""

    prompt: List[int]
    next: int            # next prompt position to prefill
    end: int             # stop (exclusive): len(prompt) - 1


class PrefillPlanner:
    """Splits admitted prompts into chunks and batches them into calls.

    ``start(slot, prompt)`` registers a slot whose prompt needs
    prefilling (returns False for single-token prompts, which go
    straight to decode); ``next_call()`` assembles one padded
    ``(num_slots, chunk)`` batch covering every registered slot's next
    chunk and advances the plan.  The engine calls ``next_call`` at most
    once per step while ``has_work``.
    """

    def __init__(self, num_slots: int, chunk: int):
        assert chunk > 0
        self.num_slots = num_slots
        self.chunk = chunk
        self._jobs: Dict[int, PrefillJob] = {}
        self.calls = 0
        self.tokens_prefilled = 0

    # ------------------------------------------------------------ plan ----

    def start(self, slot: int, prompt: Sequence[int],
              start: int = 0) -> bool:
        """Register a freshly admitted slot; False = nothing to prefill
        (the prompt is a single token — decode consumes it directly).

        ``start`` skips positions already resident in the slot's cache —
        the shared-prefix hit path: adopted pages cover ``0 .. start-1``,
        so prefill begins at ``start`` (a full hit, ``start >= end``,
        skips prefill entirely and TTFT collapses to queue +
        first-decode)."""
        assert slot not in self._jobs, f"slot {slot} already prefilling"
        end = len(prompt) - 1
        if end - start <= 0:
            return False
        self._jobs[slot] = PrefillJob(list(prompt), start, end)
        return True

    def cancel(self, slot: int) -> None:
        """Drop a slot's remaining plan (preemption): the engine
        re-ingests the whole prefix on re-admission."""
        self._jobs.pop(slot, None)

    @property
    def has_work(self) -> bool:
        return bool(self._jobs)

    def in_prefill(self, slot: int) -> bool:
        return slot in self._jobs

    def next_pos(self, slot: int) -> int:
        """The slot's next unwritten prompt position — the engine parks
        the slot's decode-passenger write there (the next chunk rewrites
        it, so the junk line is never read)."""
        return self._jobs[slot].next

    # ------------------------------------------------------------ call ----

    def next_call(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 List[int]]:
        """Assemble one batched prefill call and advance the plan.

        Returns ``(tokens (num_slots, chunk) int32, pos (num_slots,)
        int32, lens (num_slots,) int32, finished slots)`` — every
        registered slot contributes its next ``<= chunk`` prompt tokens;
        rows with ``lens == 0`` are padding lanes the device masks off.
        Slots whose last chunk this is are returned in ``finished`` and
        leave the plan (the engine flips them to decode phase).
        """
        assert self._jobs, "next_call with no prefill work"
        tokens = np.zeros((self.num_slots, self.chunk), np.int32)
        pos = np.zeros(self.num_slots, np.int32)
        lens = np.zeros(self.num_slots, np.int32)
        finished: List[int] = []
        for slot in sorted(self._jobs):
            job = self._jobs[slot]
            n = min(self.chunk, job.end - job.next)
            tokens[slot, :n] = job.prompt[job.next:job.next + n]
            pos[slot] = job.next
            lens[slot] = n
            job.next += n
            if job.next >= job.end:
                finished.append(slot)
        for slot in finished:
            del self._jobs[slot]
        self.calls += 1
        self.tokens_prefilled += int(lens.sum())
        return tokens, pos, lens, finished

    # ------------------------------------------------------------ audit ----

    def audit(self, active_slots: Set[int]) -> None:
        """Planner invariants (raises ``AuditViolation``): every job
        belongs to a currently active slot (a cancelled/retired slot
        must not keep a plan), and its cursor stays inside the prompt."""
        for slot, job in self._jobs.items():
            if slot not in active_slots:
                raise AuditViolation(
                    f"prefill job for slot {slot} which is not active")
            if not (0 <= job.next <= job.end <= len(job.prompt)):
                raise AuditViolation(
                    f"prefill cursor out of range for slot {slot}: "
                    f"next={job.next} end={job.end} "
                    f"prompt={len(job.prompt)}")

    # --------------------------------------------------------- reports ----

    def register_metrics(self, reg) -> None:
        reg.gauge("prefill.calls", lambda: self.calls)
        reg.gauge("prefill.tokens", lambda: self.tokens_prefilled)
        reg.gauge("prefill.in_flight", lambda: len(self._jobs))

    def report(self) -> Dict:
        lanes = self.calls * self.num_slots * self.chunk
        return {
            "chunk": self.chunk,
            "calls": self.calls,
            "tokens_prefilled": self.tokens_prefilled,
            "in_flight": len(self._jobs),
            "lane_utilization": (self.tokens_prefilled / lanes
                                 if lanes else None),
        }
