"""Typed error hierarchy for the serving engine.

Every failure the engine can hand a caller derives from ``ServeError``,
so clients catch one base instead of memorising per-module exception
types.  The concrete classes keep their historical stdlib bases
(``ValueError`` for submit-time rejection, ``RuntimeError`` for
allocator exhaustion) so pre-hierarchy callers keep working.

Terminal request outcomes map onto this hierarchy: an EXPIRED request
records a ``DeadlineExceeded``, a SHED request a ``ServeOverloaded``,
and ``Request.result()`` re-raises whichever was recorded.
"""
from __future__ import annotations

from typing import Optional

__all__ = [
    "AuditViolation",
    "DeadlineExceeded",
    "OutOfPages",
    "RequestRejected",
    "ServeError",
    "ServeOverloaded",
]


class ServeError(Exception):
    """Base of every typed serving-engine error."""


class RequestRejected(ServeError, ValueError):
    """A submitted request can never be served under the engine's
    configuration (prompt too long for ``max_len``, need exceeds the
    page pool, empty prompt, non-positive token budget).  Raised at
    ``submit()`` time — rejection is immediate, never queued."""


class OutOfPages(ServeError, RuntimeError):
    """A page pool ran out of free pages mid-flight.

    Under the strict (worst-case) commitment policy this is converted
    to an AssertionError — admission guarantees it cannot happen — and
    under ``preempt=True`` it is caught internally and answered by
    preempting a slot.  It escapes to callers only via direct
    ``PagedKVCache`` use."""

    def __init__(self, bname: str):
        super().__init__(f"page pool exhausted for block {bname!r}")
        self.bname = bname


class ServeOverloaded(ServeError):
    """Admission-control backpressure: the engine is shedding load
    because queue depth or estimated TTFT exceeds its budget.  Raised
    by ``submit()`` for requests due immediately; queued requests that
    become due while the engine is overloaded are shed silently with
    this error recorded on the request."""

    def __init__(self, reason: str, queue_depth: Optional[int] = None,
                 est_ttft_s: Optional[float] = None):
        super().__init__(reason)
        self.reason = reason
        self.queue_depth = queue_depth
        self.est_ttft_s = est_ttft_s


class DeadlineExceeded(ServeError):
    """A request missed its ``deadline_ms`` budget (measured from the
    moment its arrival came due) and was expired — queued, mid-prefill,
    or mid-decode.  Recorded on the request; partial tokens are kept."""


class AuditViolation(ServeError, AssertionError):
    """A step-level invariant audit failed: refcount drift, free-list /
    referenced overlap, page-table aliasing, an illegal request-state
    transition, or non-finite logits with no corrupted tensor to
    quarantine.  Always a bug (or an unrecoverable injected fault) —
    never part of normal control flow."""
