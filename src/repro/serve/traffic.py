"""Memory-traffic observatory: per-tensor HBM attribution, compiled-HLO
cross-check, and energy-projected serving metrics.

The paper's headline result is a *memory-access* number (86 % less SRAM
buffer access than SparTen buys the 2.5× power efficiency), yet the
serving engine's traffic story used to be one analytically-modeled
aggregate (``weight_stream``) that was never attributed below
"stack + head" and never validated against what XLA actually compiles.
This module is the traffic-side counterpart of the PR-8 telemetry spine:

* **Ledger** — modeled HBM bytes decomposed into a (tensor-role ×
  phase) ledger: attention q/k/v/o, MLP, MoE router/expert stacks, SSM
  mixers, LM head, plus KV page reads/writes and prefix-reuse savings.
  Role rows reuse the manifest's *exact* per-entry accounting
  (``int(round(bytes × activated_scale))``), so the ledger sums to the
  ``weight_stream`` aggregates to the byte — pinned by test.  Per-phase
  byte counters live in the engine's ``MetricsRegistry`` (always on,
  like every other subsystem counter) and, with ``--trace-out``, are
  emitted as Chrome trace counter tracks (``hbm.decode`` /
  ``hbm.prefill``).

* **Cross-check** — ``crosscheck()`` lowers the engine's own jitted
  decode/prefill steps, runs the while-aware HLO analyzer
  (``launch/hlo_counters``) over the compiled text, and compares the
  counted bytes against ``modeled_executed()`` — the bytes the chosen
  dispatch *should* fetch.  Note the two sides of DESIGN_PACKED.md §6:
  on the xla-oracle dispatch (CI) the compiled program fetches the
  pack-time ``dense_cache`` renderings and capacity-dispatch MoE runs
  *every* stored expert, so the executed model counts full dense stored
  bytes there; only the Pallas dispatch streams the compressed bitmap
  bytes the serving ledger models.  The ratio must sit inside a
  tolerance band — the 2.4×/3.22× weight-HBM claims stop being
  self-graded.

* **Energy + roofline projection** — the ledger projects through
  ``core/energy.energy_dataflow`` into pJ/token and TOPS/W figures
  (28 nm event model, Table I constants) and each phase lands on the
  roofline (``launch/hlo_analysis.roofline``), so ``report()["traffic"]``
  says not just how many bytes moved but what they cost and which wall
  the phase sits against.

The ledger itself is always on (pure host-int arithmetic folded into
the registry, matching the report/metrics contract); the *artifact*
(``traffic_out``) and the trace counter tracks engage only when asked
for, and the cross-check compiles HLO only when invoked — off is
bit-identical and allocation-free, per the PR-8 overhead contract.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import NUM_MACS, energy_dataflow, tops_per_watt
from repro.launch.hlo_analysis import roofline
from repro.launch.hlo_counters import analyze as hlo_analyze
from repro.models.model import attn_capacity
from repro.serve.packed import (ROUTED_EXPERT, activated_scale,
                                entry_device_bytes)

__all__ = ["TrafficLedger", "role_of", "TRAFFIC_PHASES", "TRAFFIC_KINDS",
           "CROSSCHECK_BANDS"]

#: the ledger's phase × kind counter grid (registry names
#: ``traffic.<phase>.<kind>_bytes``)
TRAFFIC_PHASES = ("decode", "prefill")
TRAFFIC_KINDS = ("weight", "kv_read", "kv_write")

_ATTN_ROLES = {"wq": "attn.wq", "wk": "attn.wk", "wv": "attn.wv",
               "wo": "attn.wo"}
_SSM_COMPS = {"mamba", "rwkv", "rwkv_cm"}

#: per-phase compiled-vs-modeled bytes ratio bands.
#:
#: ``modeled_executed`` is a *fetch floor* — bytes the dispatch must
#: read at least once — so the lower bound is 1.0: a ratio below it
#: means the model over-counts what the compiled program executes.  The
#: roof absorbs the analyzer's instruction-granularity re-charging
#: (each weight is read by its f32→compute convert fusion *and* by the
#: dot, ~3–4× the stored bytes) plus activation intermediates, which
#: dominate at smoke scale where weights are tiny; prefill processes
#: chunk×slots tokens per call, so its activation share is larger
#: still.  Measured across {packed, dense} × {contig, paged} on the
#: smoke archs: decode 4.3–5.4, prefill 17.9–19.6 — the roofs leave
#: ~25–50 % headroom, and the CI budget file pins the exact byte
#: counts far tighter than the band.
CROSSCHECK_BANDS = {"decode": (1.0, 8.0), "prefill": (1.0, 24.0)}


def role_of(path: str) -> str:
    """Map a manifest path (``blocks/{b}/{comp}/{name}``) to its ledger
    role — the (tensor × layer-role) axis of the attribution."""
    _, _, comp, name = path.split("/")
    if name == "norm":
        return "norm"
    if comp == "attn":
        return _ATTN_ROLES.get(name, "attn.other")
    if comp == "mlp":
        return "mlp"
    if comp == "moe":
        if name == "router":
            return "moe.router"
        if (comp, name) in ROUTED_EXPERT:
            return "moe.experts"
        return "moe.other"
    if comp in _SSM_COMPS:
        return "ssm"
    return "other"


class TrafficLedger:
    """Per-role / per-phase HBM traffic attribution over one engine.

    Holds no model state of its own: role rows are recomputed lazily
    from the live manifest (quarantines call ``invalidate()``), KV
    geometry is precomputed from the config, and the running per-phase
    byte counters are ordinary registry ``Counter``s.
    """

    def __init__(self, engine) -> None:
        self.eng = engine
        cfg = engine.cfg
        itemsize = jnp.dtype(cfg.compute_dtype).itemsize
        # one token's K+V line for one pattern block, across all periods
        # (the same constant paging.py sizes its pools with)
        line = (2 * cfg.num_periods * cfg.num_kv_heads
                * cfg.resolved_head_dim * itemsize)
        self._attn: List[Tuple[int, int]] = [
            (attn_capacity(blk, engine.max_len), line)
            for blk in cfg.pattern if blk.mixer == "attn"]
        self._line_total = sum(ln for _, ln in self._attn)
        self._roles: Optional[Dict[str, Dict[str, int]]] = None
        self._crosscheck: Optional[Dict] = None
        self._c: Dict[Tuple[str, str], object] = {}

    def register_metrics(self, reg) -> None:
        for phase in TRAFFIC_PHASES:
            for kind in TRAFFIC_KINDS:
                self._c[(phase, kind)] = reg.counter(
                    f"traffic.{phase}.{kind}_bytes",
                    help=f"modeled {kind} HBM bytes, {phase} phase")

    # ------------------------------------------------------------ ledger ----

    def invalidate(self) -> None:
        """Drop the cached role rows — called after a quarantine flips a
        manifest entry to dense, so the next render re-walks the live
        manifest."""
        self._roles = None

    def per_role(self) -> Dict[str, Dict[str, int]]:
        """Modeled per-step weight-HBM bytes by ledger role.

        Reuses the manifest's per-entry accounting verbatim — the same
        ``int(round(bytes × activated_scale))`` per tensor that
        ``PackedModel.stream_report`` sums, grouped by role instead of
        flattened — so the role rows sum *exactly* to the
        ``weight_stream`` aggregates (the dense-baseline walk mirrors
        ``ServeEngine.weight_stream_report`` the same way).  The
        ``device_*`` columns apply the same per-entry rule divided by
        the tensor's shard count (``packed.entry_device_bytes`` —
        replicated tensors charge whole), so they sum to the engine's
        ``device_*_bytes_per_step`` aggregates by construction."""
        if self._roles is not None:
            return self._roles
        eng = self.eng
        cfg = eng.cfg
        activated = (eng.num_slots * cfg.top_k
                     if cfg.num_experts else None)
        roles: Dict[str, Dict[str, int]] = {}

        def add(role: str, sparse: int, dense: int,
                dev_sparse: Optional[int] = None,
                dev_dense: Optional[int] = None) -> None:
            row = roles.setdefault(
                role, {"sparse_bytes": 0, "dense_bytes": 0,
                       "device_sparse_bytes": 0, "device_dense_bytes": 0,
                       "tensors": 0})
            row["sparse_bytes"] += sparse
            row["dense_bytes"] += dense
            row["device_sparse_bytes"] += (
                sparse if dev_sparse is None else dev_sparse)
            row["device_dense_bytes"] += (
                dense if dev_dense is None else dev_dense)
            row["tensors"] += 1

        if eng.packed is not None:
            for e in eng.packed.manifest:
                scale = activated_scale(e.experts, activated)
                add(role_of(e.path),
                    int(round(e.sparse_bytes * scale)),
                    int(round(e.dense_bytes * scale)),
                    entry_device_bytes(e, "sparse_bytes", activated),
                    entry_device_bytes(e, "dense_bytes", activated))
        else:
            for bname, bdict in eng.params["blocks"].items():
                for comp, tensors in bdict.items():
                    for name, leaf in tensors.items():
                        b = (int(np.prod(leaf.shape))
                             * leaf.dtype.itemsize)
                        routed = (leaf.shape[1]
                                  if (comp, name) in ROUTED_EXPERT
                                  and leaf.ndim == 4 else 0)
                        sb = int(round(
                            b * activated_scale(routed, activated)))
                        add(role_of(f"blocks/{bname}/{comp}/{name}"),
                            sb, sb)
        head_dense = (cfg.d_model * cfg.vocab_size
                      * np.dtype(np.float32).itemsize)
        head_sparse = (eng.lm_weight.hbm_bytes
                       if eng.lm_weight is not None else head_dense)
        head_sh = (eng.lm_weight.shard[1]
                   if eng.lm_weight is not None
                   and eng.lm_weight.shard is not None else 1)
        add("head", head_sparse, head_dense,
            head_sparse // head_sh, head_dense)
        self._roles = roles
        return roles

    def _totals(self) -> Tuple[int, int, int]:
        """(sparse, dense, stack-only sparse) per-step weight bytes."""
        roles = self.per_role()
        sparse = sum(r["sparse_bytes"] for r in roles.values())
        dense = sum(r["dense_bytes"] for r in roles.values())
        return sparse, dense, sparse - roles["head"]["sparse_bytes"]

    # ------------------------------------------------------- step hooks ----

    def on_decode(self, positions: Sequence[int]) -> Dict[str, int]:
        """Account one decode step: the full weight stream (stack +
        head) plus per-slot KV line reads up to each live position and
        one line write per decoding slot.  Returns the step's byte
        deltas for the trace counter track."""
        weight, _, _ = self._totals()
        read = 0
        for p in positions:
            for cap, line in self._attn:
                read += min(p + 1, cap) * line
        write = len(positions) * self._line_total
        self._c[("decode", "weight")].inc(weight)
        self._c[("decode", "kv_read")].inc(read)
        self._c[("decode", "kv_write")].inc(write)
        return {"weight_bytes": weight, "kv_read_bytes": read,
                "kv_write_bytes": write}

    def on_prefill(self, pos: Sequence[int],
                   lens: Sequence[int]) -> Dict[str, int]:
        """Account one batched prefill call: the stack streams once (no
        head in the prefill step), each active lane writes ``len`` KV
        lines and attends over its whole resident prefix."""
        _, _, stack = self._totals()
        read = write = 0
        for p, n in zip(pos, lens):
            n = int(n)
            if n <= 0:
                continue
            write += n * self._line_total
            end = int(p) + n
            for cap, line in self._attn:
                read += min(end, cap) * line
        self._c[("prefill", "weight")].inc(stack)
        self._c[("prefill", "kv_read")].inc(read)
        self._c[("prefill", "kv_write")].inc(write)
        return {"weight_bytes": stack, "kv_read_bytes": read,
                "kv_write_bytes": write}

    # ------------------------------------------------------- projections ----

    def _phase_bytes(self, phase: str) -> Dict[str, int]:
        return {f"{k}_bytes": self._c[(phase, k)].value
                for k in TRAFFIC_KINDS}

    def _energy(self) -> Dict[str, float]:
        """pJ/token + TOPS/W under the 28 nm event model.  MACs per
        token = activated dense weight elements (every touched element
        multiplies once per token); the SRAM term is the measured
        per-token traffic once steps have run, else the modeled
        per-step stream amortised over the batch."""
        eng = self.eng
        sparse, dense, _ = self._totals()
        macs = dense // np.dtype(np.float32).itemsize
        tokens = eng._c_slot_steps.value
        if tokens > 0:
            w_bytes = sum(self._c[(ph, "weight")].value
                          for ph in TRAFFIC_PHASES)
            kv_bytes = sum(self._c[(ph, k)].value
                           for ph in TRAFFIC_PHASES
                           for k in ("kv_read", "kv_write"))
            w_tok = w_bytes / tokens
            kv_tok = kv_bytes / tokens
        else:
            w_tok = sparse / max(eng.num_slots, 1)
            kv_tok = float(self._line_total)
        w_tok_dense = w_tok * (dense / sparse) if sparse else w_tok
        cycles = macs / NUM_MACS
        e_s = energy_dataflow(macs, w_tok + kv_tok, cycles)
        e_d = energy_dataflow(macs, w_tok_dense + kv_tok, cycles)
        return {
            "macs_per_token": int(macs),
            "pj_per_token": e_s / 1e-12,
            "pj_per_token_dense": e_d / 1e-12,
            "tops_per_watt": tops_per_watt(macs, e_s),
            "tops_per_watt_dense": tops_per_watt(macs, e_d),
        }

    def _roofline(self) -> Dict[str, Dict]:
        """Place each phase on the v5e roofline: measured per-step bytes
        (modeled per-step stream before any step has run) against the
        phase's useful FLOPs."""
        eng = self.eng
        sparse, dense, stack_sparse = self._totals()
        roles = self.per_role()
        macs_tok = dense / np.dtype(np.float32).itemsize
        stack_dense = (dense - roles["head"]["dense_bytes"])
        out: Dict[str, Dict] = {}
        dec = eng._c_decode_steps.value
        if dec > 0:
            b = sum(self._phase_bytes("decode").values()) / dec
        else:
            b = float(sparse + eng.num_slots * self._line_total)
        out["decode"] = roofline(2.0 * macs_tok * eng.num_slots, b, 0.0)
        pre = eng._c_prefill_steps.value
        if pre > 0:
            pb = sum(self._phase_bytes("prefill").values()) / pre
            tok_per_call = (
                self._c[("prefill", "kv_write")].value
                / (self._line_total * pre) if self._line_total else
                float(eng.prefill_chunk * eng.num_slots))
            pf = 2.0 * (stack_dense / 4.0) * tok_per_call
            out["prefill"] = roofline(pf, pb, 0.0)
        return out

    # -------------------------------------------------------- crosscheck ----

    def _dispatch(self) -> str:
        """Which weight path the compiled program actually fetches."""
        eng = self.eng
        if eng.packed is None:
            return "dense"
        if any(bw.dense_cache is not None
               for _, bw in eng.packed.leaves()):
            return "xla-oracle"
        return "pallas"

    def modeled_executed(self, phase: str) -> Dict[str, int]:
        """Bytes the compiled step *should* fetch, by component.

        Weights follow the dispatch (DESIGN_PACKED.md §6 modeled vs
        executed): the xla-oracle path reads the pack-time dense
        renderings and capacity-dispatch MoE executes every stored
        expert, so packed leaves with a ``dense_cache`` charge full
        dense stored bytes, unscaled; the Pallas path charges the
        compressed ``hbm_bytes``; fallback leaves charge the dense
        params tensor.  KV charges the resident lines the step touches:
        the whole contiguous k/v leaves, or the padded per-slot page
        view under paging."""
        eng = self.eng
        weights = 0
        if eng.packed is not None:
            for bname, bdict in eng.packed.blocks.items():
                for comp, tensors in bdict.items():
                    for name, bw in tensors.items():
                        if bw is None:
                            leaf = eng.params["blocks"][bname][comp][name]
                            weights += (int(np.prod(leaf.shape))
                                        * leaf.dtype.itemsize)
                        elif bw.dense_cache is not None:
                            weights += int(bw.dense_cache.size
                                           * bw.dense_cache.dtype.itemsize)
                        else:
                            weights += bw.hbm_bytes
        else:
            for bdict in eng.params["blocks"].values():
                for tensors in bdict.values():
                    for leaf in tensors.values():
                        weights += (int(np.prod(leaf.shape))
                                    * leaf.dtype.itemsize)
        head = 0
        if phase == "decode":
            head_dense = (eng.cfg.d_model * eng.cfg.vocab_size
                          * np.dtype(np.float32).itemsize)
            if eng.lm_weight is None or \
                    eng.lm_weight.dense_cache is not None:
                head = head_dense
            else:
                head = eng.lm_weight.hbm_bytes
        if eng.page_len:
            kv = sum(p.page_slots * eng.kv.page_len * p.line_bytes
                     for p in eng.kv.pools.values()) * eng.num_slots
        else:
            kv = eng.kv.reserved_kv_bytes()
        return {"weight_bytes": int(weights), "head_bytes": int(head),
                "kv_bytes": int(kv),
                "total_bytes": int(weights + head + kv)}

    def _lowered(self, phase: str):
        """Lower the engine's own jitted step with the exact argument
        assembly ``ServeEngine._decode`` / ``_prefill`` uses (lowering
        never executes, so donation is inert and the live cache is
        safe)."""
        eng = self.eng
        if phase == "prefill":
            kw = dict(packed=(eng.packed.blocks
                              if eng.packed is not None else None))
            if eng.page_len:
                kw["page_tables"] = eng.kv.tables()
            z = np.zeros((eng.num_slots, eng.prefill_chunk), np.int32)
            zl = np.zeros(eng.num_slots, np.int32)
            return eng._jit_prefill.lower(
                eng.params, eng.kv.cache, jnp.asarray(z),
                jnp.asarray(zl), jnp.asarray(zl), **kw)
        packed = eng.packed.blocks if eng.packed is not None else None
        kw = dict(lm_weight=eng.lm_weight, packed=packed)
        if eng.page_len:
            kw["page_tables"] = eng.kv.tables()
        if eng._use_sampling:
            kw.update(sample_keys=jnp.asarray(eng._keys),
                      temperature=jnp.asarray(eng._temp))
            if eng._use_topk_vec:
                kw["top_ks"] = jnp.asarray(eng._topk)
        pos = jnp.asarray(eng._pos)
        if eng.cfg.frontend == "frames":
            ekey = jax.random.fold_in(eng._embed_key, eng._steps)
            return eng._jit_step.lower(eng.params, eng.kv.cache, None,
                                       pos, embed_rng=ekey, **kw)
        tok = jnp.asarray(eng._tok[:, None])
        return eng._jit_step.lower(eng.params, eng.kv.cache, tok, pos,
                                   **kw)

    def crosscheck(self, bands: Optional[Dict[str, Tuple[float, float]]]
                   = None) -> Dict:
        """Compile the decode (and, when chunked prefill is on, the
        prefill) step, count its bytes/FLOPs with the while-aware HLO
        analyzer, and compare against ``modeled_executed`` — the
        modeled-vs-compiled contract.  The result is cached into
        ``report()["traffic"]["crosscheck"]`` and the ``traffic_out``
        artifact."""
        eng = self.eng
        bands = dict(CROSSCHECK_BANDS, **(bands or {}))
        out: Dict = {"dispatch": self._dispatch()}
        phases = ["decode"]
        if eng._jit_prefill is not None:
            phases.append("prefill")
        for phase in phases:
            lo, hi = bands[phase]
            compiled = self._lowered(phase).compile()
            counted = hlo_analyze(compiled.as_text())
            modeled = self.modeled_executed(phase)
            ratio = (counted["bytes"] / modeled["total_bytes"]
                     if modeled["total_bytes"] else float("nan"))
            out[phase] = {
                "compiled_bytes": int(counted["bytes"]),
                "compiled_flops": float(counted["flops"]),
                "modeled": modeled,
                "ratio": float(ratio),
                "tolerance": [float(lo), float(hi)],
                "within_band": bool(lo <= ratio <= hi),
            }
        self._crosscheck = out
        return out

    # ----------------------------------------------------------- reports ----

    def report(self) -> Dict:
        """The ``report()["traffic"]`` section — ledger, KV accounting,
        phase totals, energy projection, per-phase roofline, and the
        cross-check verdict when one has been run."""
        eng = self.eng
        roles = self.per_role()
        sparse, dense, _ = self._totals()
        saved = 0
        if eng.page_len and getattr(eng, "prefix_reuse", False):
            saved = eng.kv.hit_tokens * self._line_total
        return {
            "per_role": {k: dict(v) for k, v in sorted(roles.items())},
            "weight": {
                "sparse_bytes_per_step": sparse,
                "dense_bytes_per_step": dense,
                "reduction": dense / sparse if sparse else 1.0,
                "shards": (eng.packed.shards
                           if eng.packed is not None else 1),
                "device_sparse_bytes_per_step": sum(
                    r["device_sparse_bytes"] for r in roles.values()),
                "device_dense_bytes_per_step": sum(
                    r["device_dense_bytes"] for r in roles.values()),
            },
            "kv": {
                "line_bytes_per_token": self._line_total,
                "read_bytes": (self._c[("decode", "kv_read")].value
                               + self._c[("prefill", "kv_read")].value),
                "write_bytes": (self._c[("decode", "kv_write")].value
                                + self._c[("prefill", "kv_write")].value),
                "prefix_saved_bytes": saved,
            },
            "phases": {
                "decode": {"steps": eng._c_decode_steps.value,
                           **self._phase_bytes("decode")},
                "prefill": {"calls": eng._c_prefill_steps.value,
                            **self._phase_bytes("prefill")},
            },
            "energy": self._energy(),
            "roofline": self._roofline(),
            "crosscheck": self._crosscheck,
        }

    def write(self, path: str) -> None:
        """Write the traffic artifact (running the cross-check first if
        it has not run) — the input to ``scripts/traffic_report.py``,
        the CI budget gate, and ``benchmarks/roofline.py``'s serving
        mode."""
        if self._crosscheck is None:
            self.crosscheck()
        doc = {
            "schema": "repro.serve.traffic/v1",
            "arch": self.eng.cfg.name,
            "sparsity": float(self.eng.sparsity),
            "num_slots": int(self.eng.num_slots),
            "traffic": self.report(),
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, allow_nan=False)
            f.write("\n")
