"""Arrival traces for the serving benchmark.

Arrival offsets are measured in *decode steps*, not wall seconds, so a
trace schedules identically on any host — the scheduler's behaviour under
load is deterministic and testable while wall-clock latencies are still
measured for reporting.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def poisson_trace(n_requests: int, rate: float, seed: int = 0,
                  prompt_len: Tuple[int, int] = (1, 4),
                  max_new: Tuple[int, int] = (8, 24),
                  vocab_size: int = 256) -> List[dict]:
    """Seeded Poisson arrival process: exponential inter-arrival gaps with
    mean ``1/rate`` decode steps; prompts and budgets drawn uniformly."""
    assert rate > 0
    r = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += float(r.exponential(1.0 / rate))
        plen = int(r.integers(prompt_len[0], prompt_len[1], endpoint=True))
        out.append({
            "prompt": [int(x) for x in r.integers(0, vocab_size, plen)],
            "max_new_tokens": int(r.integers(max_new[0], max_new[1],
                                             endpoint=True)),
            "arrival": t,
        })
    return out


def percentiles(values: Sequence[float], qs=(50, 99)) -> dict:
    if not values:
        return {f"p{q}": float("nan") for q in qs}
    arr = np.asarray(values, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}
