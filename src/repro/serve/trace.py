"""Arrival traces for the serving benchmark.

Arrival offsets are measured in *decode steps*, not wall seconds, so a
trace schedules identically on any host — the scheduler's behaviour under
load is deterministic and testable while wall-clock latencies are still
measured for reporting.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def poisson_trace(n_requests: int, rate: float, seed: int = 0,
                  prompt_len: Tuple[int, int] = (1, 4),
                  max_new: Tuple[int, int] = (8, 24),
                  vocab_size: int = 256) -> List[dict]:
    """Seeded Poisson arrival process: exponential inter-arrival gaps with
    mean ``1/rate`` decode steps; prompts and budgets drawn uniformly."""
    assert rate > 0
    r = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += float(r.exponential(1.0 / rate))
        plen = int(r.integers(prompt_len[0], prompt_len[1], endpoint=True))
        out.append({
            "prompt": [int(x) for x in r.integers(0, vocab_size, plen)],
            "max_new_tokens": int(r.integers(max_new[0], max_new[1],
                                             endpoint=True)),
            "arrival": t,
        })
    return out


def percentiles(values: Sequence[float], qs=(50, 99)) -> dict:
    if not values:
        return {f"p{q}": float("nan") for q in qs}
    arr = np.asarray(values, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class RollingStat:
    """Streaming latency aggregate: exact count/mean plus a bounded
    reservoir for percentiles.

    The engine folds each request's latencies in at retire time instead
    of rescanning its (now bounded) request history on every
    ``report()`` call.  Up to ``cap`` samples the reservoir holds every
    value, so short-trace percentiles are *identical* to the old
    full-scan ``percentiles()``; past ``cap`` it degrades to a
    uniform-without-replacement sample (Vitter's algorithm R) with a
    seeded RNG, so reports stay deterministic for a given trace.
    """

    def __init__(self, cap: int = 2048, seed: int = 0):
        assert cap >= 1
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self._sample: List[float] = []
        self._rng = np.random.default_rng(seed)

    def add(self, value) -> None:
        if value is None:
            return
        v = float(value)
        self.count += 1
        self.total += v
        if len(self._sample) < self.cap:
            self._sample.append(v)
        else:
            j = int(self._rng.integers(self.count))
            if j < self.cap:
                self._sample[j] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentiles(self, qs=(50, 99)) -> dict:
        return percentiles(self._sample, qs)
