"""rwkv6-3b [ssm]: 32L d=2560 (attn-free) d_ff=8960 vocab=65536.

RWKV-6 "Finch" — data-dependent decay time-mix + squared-relu channel-mix.
State-based decode makes the 500k-context cell natural.  [arXiv:2404.05892]
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        d_model=2560, num_layers=32, num_heads=40, num_kv_heads=40,
        d_ff=8960, vocab_size=65536, rwkv_head_dim=64,
        pattern=(BlockCfg(mixer="rwkv", ffn="rwkv_cm"),),
        norm="ln", act="relu",
        tie_embeddings=False, max_seq_len=1_048_576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        d_model=64, num_layers=2, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, rwkv_head_dim=16,
        pattern=(BlockCfg(mixer="rwkv", ffn="rwkv_cm"),),
        norm="ln", act="relu", tie_embeddings=False, max_seq_len=64,
    )
