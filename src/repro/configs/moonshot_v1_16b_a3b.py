"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) per-expert d_ff=1408,
MoE 64 experts top-6, vocab=163840.  [hf:moonshotai/Moonlight-16B-A3B]

NOTE: the assigned hyperparameters give 27.7B total / 3.6B active params —
active matches the "a3b" moniker; the "16b" nameplate would require a
different expert shape than assigned. We implement the assignment exactly.
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        d_model=2048, num_layers=48, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=163_840,
        pattern=(BlockCfg(mixer="attn", ffn="moe"),),
        num_experts=64, top_k=6,
        norm="rmsnorm", act="silu", rope_theta=50_000.0,
        tie_embeddings=True, max_seq_len=8192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke",
        d_model=64, num_layers=2, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256,
        pattern=(BlockCfg(mixer="attn", ffn="moe"),),
        num_experts=8, top_k=2,
        norm="rmsnorm", act="silu", max_seq_len=64,
    )
