"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global, 128k.  34 layers do not tile by a 6-block period, so the
pattern is one 17-block half (15 local : 2 global, globals at 5 and 11)
repeated twice — the closest 5:1 tiling of 34 layers (DESIGN.md §4).
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import BlockCfg, ModelConfig

_L = BlockCfg(mixer="attn", window=1024)
_G = BlockCfg(mixer="attn", window=None)
_PATTERN = (_L, _L, _L, _L, _L, _G, _L, _L, _L, _L, _L, _G, _L, _L, _L, _L,
            _L)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        d_model=2560, num_layers=34, num_heads=8, num_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256,
        pattern=_PATTERN, qk_norm=True, embed_scale=True,
        norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
        tie_embeddings=True, max_seq_len=131_072,
    )


def smoke_config() -> ModelConfig:
    sl = BlockCfg(mixer="attn", window=8)
    sg = BlockCfg(mixer="attn")
    return ModelConfig(
        name="gemma3-4b-smoke",
        d_model=64, num_layers=6, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        pattern=(sl, sl, sg, sl, sl, sg), qk_norm=True, embed_scale=True,
        norm="rmsnorm", act="silu", max_seq_len=64,
    )
