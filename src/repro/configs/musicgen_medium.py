"""musicgen-medium [audio]: 48L d=1536 24H (kv=24) d_ff=6144 vocab=2048.

Decoder-only transformer over EnCodec tokens.  The EnCodec frontend is a
STUB per the assignment: ``input_specs`` provides precomputed frame
embeddings (B, S, D); the LM head predicts the next codec token (vocab
2048).  Positional encoding adapted to RoPE (DESIGN.md §4).
[arXiv:2306.05284; hf]
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        d_model=1536, num_layers=48, num_heads=24, num_kv_heads=24,
        d_ff=6144, vocab_size=2048,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln", act="gelu", rope_theta=10_000.0,
        tie_embeddings=False, max_seq_len=32_768,
        frontend="frames",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        d_model=64, num_layers=2, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln", act="gelu", tie_embeddings=False, max_seq_len=64,
        frontend="frames",
    )
