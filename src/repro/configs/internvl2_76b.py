"""internvl2-76b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT + LLaMA-3-70B-class backbone.  The InternViT frontend is a STUB
per the assignment: ``input_specs`` provides 256 precomputed patch
embeddings prepended to the token sequence; loss is masked over the patch
region.  [arXiv:2404.16821; unverified]
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        d_model=8192, num_layers=80, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128_256,
        pattern=(BlockCfg(mixer="attn"),),
        norm="rmsnorm", act="silu", rope_theta=500_000.0,
        tie_embeddings=False, max_seq_len=32_768,
        frontend="patches", frontend_len=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke",
        d_model=64, num_layers=2, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        pattern=(BlockCfg(mixer="attn"),),
        norm="rmsnorm", act="silu", tie_embeddings=False, max_seq_len=64,
        frontend="patches", frontend_len=4,
    )
