"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local:global attention interleave (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import BlockCfg, ModelConfig

_PATTERN = tuple([BlockCfg(mixer="attn", window=1024)] * 5
                 + [BlockCfg(mixer="attn", window=None)])


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        d_model=3840, num_layers=48, num_heads=16, num_kv_heads=8,
        d_ff=15360, vocab_size=262144, head_dim=256,
        pattern=_PATTERN, qk_norm=True, embed_scale=True,
        norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
        tie_embeddings=True, max_seq_len=131_072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke",
        d_model=64, num_layers=6, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        pattern=tuple([BlockCfg(mixer="attn", window=8)] * 5
                      + [BlockCfg(mixer="attn")]),
        qk_norm=True, embed_scale=True, norm="rmsnorm", act="silu",
        max_seq_len=64,
    )
