"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Mamba : attention 7:1 interleave (attention at period index 3), MoE 16
experts top-2 on every other layer.  [arXiv:2403.19887; hf]
"""
from repro.models.config import BlockCfg, ModelConfig


def _pattern():
    blocks = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        blocks.append(BlockCfg(mixer=mixer, ffn=ffn))
    return tuple(blocks)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        d_model=4096, num_layers=32, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536,
        pattern=_pattern(),
        num_experts=16, top_k=2,
        mamba_d_state=16, mamba_expand=2, mamba_conv=4,
        norm="rmsnorm", act="silu", rope_theta=10_000.0,
        tie_embeddings=False, max_seq_len=262_144,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        d_model=64, num_layers=8, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        pattern=_pattern(),
        num_experts=4, top_k=2,
        mamba_d_state=4, mamba_expand=2, mamba_conv=4,
        norm="rmsnorm", act="silu", tie_embeddings=False, max_seq_len=64,
    )
