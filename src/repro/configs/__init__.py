"""Architecture registry: 10 assigned archs × their input-shape sets.

``get_config(arch)`` / ``get_smoke_config(arch)`` return ``ModelConfig``s;
``SHAPES`` defines the four LM shape cells; ``cells()`` enumerates every
runnable (arch × shape) pair with skips applied per DESIGN.md §4
(long_500k only for sub-quadratic archs; all archs are decoder-style so
decode shapes run everywhere).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

_MODULES = {
    "gemma3-12b": "gemma3_12b",
    "olmo-1b": "olmo_1b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma3-4b": "gemma3_4b",
    "rwkv6-3b": "rwkv6_3b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "musicgen-medium": "musicgen_medium",
    "internvl2-76b": "internvl2_76b",
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)

# archs whose attention is sub-quadratic enough for the 500k decode cell
# (SSM / hybrid / mostly-sliding-window); pure full-attention archs skip it.
LONG_CONTEXT_ARCHS = frozenset(
    {"rwkv6-3b", "jamba-v0.1-52b", "gemma3-12b", "gemma3-4b"})


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"
    official: bool = True  # part of the assigned 40-cell matrix


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
    # extra analysis cell (EXPERIMENTS §Perf cell 3): low-latency serving —
    # the weight-streaming-bound regime the paper's technique targets
    "decode_2k_b8": ShapeCfg("decode_2k_b8", 2048, 8, "decode",
                             official=False),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke_config()


def shape_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k decode skipped"
    return True, ""


def cells(include_skipped: bool = False) -> List[Tuple[str, str, str]]:
    """All (arch, shape, skip_reason) dry-run cells (official matrix)."""
    out = []
    for arch in ARCHS:
        for shape, cfg in SHAPES.items():
            if not cfg.official:
                continue
            ok, reason = shape_supported(arch, shape)
            if ok or include_skipped:
                out.append((arch, shape, "" if ok else reason))
    return out
