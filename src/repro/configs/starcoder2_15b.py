"""starcoder2-15b [dense]: 40L d=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.

GQA + RoPE, ungated GELU MLP, standard LayerNorm.  Pure full attention —
long_500k is skipped for this arch (DESIGN.md §4).  [arXiv:2402.19173; hf]
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        d_model=6144, num_layers=40, num_heads=48, num_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln", act="gelu", rope_theta=100_000.0,
        tie_embeddings=False, max_seq_len=16_384,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke",
        d_model=64, num_layers=2, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln", act="gelu", tie_embeddings=False, max_seq_len=64,
    )
