"""olmo-1b [dense]: 16L d=2048 16H (GQA kv=16 = MHA) d_ff=8192 vocab=50304.

Non-parametric LayerNorm (the OLMo signature).  [arXiv:2402.00838; hf]
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        d_model=2048, num_layers=16, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=50304,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln_nonparam", act="silu", rope_theta=10_000.0,
        tie_embeddings=True, max_seq_len=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        d_model=64, num_layers=2, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln_nonparam", act="silu", max_seq_len=64,
    )
