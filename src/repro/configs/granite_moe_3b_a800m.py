"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) per-expert d_ff=512,
MoE 40 experts top-8, vocab=49155.  [hf:ibm-granite/granite-3.0-1b-a400m]
"""
from repro.models.config import BlockCfg, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        d_model=1536, num_layers=32, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49_155,
        pattern=(BlockCfg(mixer="attn", ffn="moe"),),
        num_experts=40, top_k=8,
        norm="rmsnorm", act="silu", rope_theta=10_000.0,
        tie_embeddings=True, max_seq_len=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke",
        d_model=64, num_layers=2, num_heads=4, num_kv_heads=2,
        d_ff=32, vocab_size=255,  # deliberately non-divisible, like 49155
        pattern=(BlockCfg(mixer="attn", ffn="moe"),),
        num_experts=5, top_k=2,
        norm="rmsnorm", act="silu", max_seq_len=64,
    )
