"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Policy (DESIGN.md §3):
  * params — Megatron-style TP over the "model" axis: column-parallel
    in-projections, row-parallel out-projections, vocab-sharded embedding
    and LM head, expert-FFN dim sharded (EP-compatible for divisible expert
    counts); small/vector params replicated;
  * batch — sharded over ("pod", "data");
  * decode caches — batch-sharded; the 500k single-sequence cells shard the
    KV cache over sequence instead (SP) since batch=1 cannot shard;
  * any dim not divisible by its axis extent falls back to replication
    (granite's 40 experts on a 16-way axis, rwkv's 40 heads, ...).

Rules are name-based over the param tree paths; block leaves carry the
leading period-stack dim, handled by spec prepending.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.model import cache_structs, param_structs
from repro.sparse.format import _TILE_ND, BitmapWeight

# (regex over path, spec over the *unstacked* leaf dims)
_RULES = [
    (r"embed$", ("model", None)),
    (r"lm_head$", (None, "model")),
    (r"\['w[qkv]'\]$", (None, "model")),
    (r"\['wo'\]$", ("model", None)),
    (r"(w_gate|w_up|cm_k)'\]$", (None, "model")),
    (r"(w_down|cm_v)'\]$", ("model", None)),
    (r"router'\]$", (None, None)),
    (r"in_proj'\]$", (None, "model")),
    (r"(conv_w|x_proj|A_log|out_proj)'\]$", ("model", None)),
    (r"(conv_b|dt_bias)'\]$", ("model",)),
    (r"\['D'\]$", ("model",)),
    (r"dt_proj'\]$", (None, "model")),
    (r"w_[rkvg]'\]$", (None, "model")),
    (r"w_o'\]$", ("model", None)),
]

_MOE_RULES = [
    (r"moe'\]\['w_(gate|up)'\]$", (None, None, "model")),
    # w_down shards the OUTPUT dim (§Perf iter 4): contracting over the
    # sharded F dim makes GSPMD all-reduce the (B, E·C, D) capacity buffer
    # (4 GB/layer for moonshot); with D sharded the combine stays local and
    # only the (B, S, D) output is gathered at the residual.
    (r"moe'\]\['w_down'\]$", (None, None, "model")),
]

# expert parallelism: shard the expert dim over "model" instead. Measured
# 1.5× fewer HBM bytes and 1.3× less wire than TP-inside-expert on
# moonshot train_4k (§Perf iter 5), so EP is the default whenever the
# expert count divides the model axis (moonshot 64/16 ✓; granite 40/16 ✗
# falls back to the iter-4 TP scheme). REPRO_MOE_EP=0/1 forces either.
_MOE_RULES_EP = [
    (r"moe'\]\['w_(gate|up)'\]$", ("model", None, None)),
    (r"moe'\]\['w_down'\]$", ("model", None, None)),
]


def _moe_rules(cfg: "ModelConfig", mesh, serve: bool):
    import os
    force = os.environ.get("REPRO_MOE_EP", "")
    if force == "1":
        return _MOE_RULES_EP
    if force == "0":
        return _MOE_RULES
    from repro.models.perf_flags import baseline_mode
    # EP regresses decode (measured 1.3× more bytes, 6× more wire on
    # moonshot decode_32k): per-token buckets are tiny, so the cross-shard
    # combine dominates — serve keeps the TP-inside-expert scheme.
    if (not baseline_mode() and not serve and cfg.num_experts
            and cfg.num_experts % mesh.shape["model"] == 0):
        return _MOE_RULES_EP
    return _MOE_RULES


def _fit(spec: tuple, shape: tuple, mesh) -> P:
    """Drop axes that don't divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        else:
            size = mesh.shape[ax]
            out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_specs(cfg: ModelConfig, mesh, serve: bool = False) -> Any:
    structs = param_structs(cfg)

    def rule_for(path, leaf):
        name = jax.tree_util.keystr(path)
        stacked = name.startswith("['blocks']")
        for pat, spec in _moe_rules(cfg, mesh, serve) + _RULES:
            if re.search(pat, name):
                full = ((None,) + spec) if stacked else spec
                if len(full) != len(leaf.shape):
                    return P()
                return _fit(full, leaf.shape, mesh)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(rule_for, structs)


def opt_specs(cfg: ModelConfig, mesh) -> Any:
    """Optimizer-state specs: params' specs + ZeRO-1-style sharding of the
    Adam moments over the data axis (§Perf iter 7) — m/v are pure
    per-element state, so each DP shard can own a slice and the weight
    update all-gathers, cutting the fp32 state footprint by the DP degree.
    First spare (None) dim that the data axis divides gets "data";
    baseline mode keeps moments param-aligned."""
    ps = param_specs(cfg, mesh)
    from repro.models.perf_flags import baseline_mode
    if baseline_mode() or "data" not in mesh.axis_names:
        return {"m": ps, "v": ps, "step": P()}
    structs = param_structs(cfg)
    dsize = mesh.shape["data"]

    def zero1(spec, leaf):
        spec = tuple(spec)
        for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
            if ax is None and dim % dsize == 0 and dim >= dsize:
                return P(*spec[:i], "data", *spec[i + 1:])
        return P(*spec)

    ms = jax.tree.map(zero1, ps, structs,
                      is_leaf=lambda x: isinstance(x, P))
    return {"m": ms, "v": ms, "step": P()}


def batch_specs(cfg: ModelConfig, mesh, batch: int) -> Any:
    """Specs for a data batch dict (tokens/targets/embeds)."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    bspec = baxes if batch % bsize == 0 and batch > 1 else None

    def spec(leaf_name):
        if leaf_name == "embeds":
            return P(bspec, None, None)
        return P(bspec, None)

    return spec


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_len: int,
                shard_seq: bool = False) -> Any:
    """PartitionSpecs matching ``cache_structs``.

    shard_seq: the long-context (batch=1) policy — KV sequence dim over
    "data" (SP), SSM inner dim over "model"; otherwise caches shard over
    batch, KV heads over "model" where divisible.
    """
    structs = cache_structs(cfg, batch, max_len)
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    bspec = baxes if batch % bsize == 0 and batch > 1 else None

    from repro.models.perf_flags import baseline_mode

    def rule_for(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = leaf.shape
        if name.endswith("['k']") or name.endswith("['v']"):
            # (P, B, C, KV, hd)
            seq_ax = ("data" if shard_seq and
                      shape[2] % mesh.shape["data"] == 0 else None)
            kv_ax = ("model" if shape[3] % mesh.shape["model"] == 0
                     else None)
            # §Perf iteration 2: when KV heads don't divide the model
            # axis, shard the cache *sequence* over "model" instead of
            # replicating 16× (partial-softmax reduce is tiny vs the read)
            if (not baseline_mode() and kv_ax is None and seq_ax is None
                    and shape[2] % mesh.shape["model"] == 0):
                seq_ax = "model"
            return P(None, bspec, seq_ax, kv_ax, None)
        if name.endswith("['h']"):          # (P, B, dI, N)
            di_ax = "model" if shape[2] % mesh.shape["model"] == 0 else None
            return P(None, bspec, di_ax, None)
        if name.endswith("['conv']"):       # (P, B, K-1, dI)
            di_ax = "model" if shape[3] % mesh.shape["model"] == 0 else None
            return P(None, bspec, None, di_ax)
        if name.endswith("['s']"):          # (P, B, H, hd, hd)
            return P(None, bspec, None, None, None)
        # x_prev / cm_x_prev: (P, B, D)
        return P(None, bspec, None)

    return jax.tree_util.tree_map_with_path(rule_for, structs)


# --------------------------------------------------------------------------
# Packed-layout sharding: the bitmap-compressed serving analogue of the
# dense _RULES above.  Column-parallel tensors split the N tile axis
# (each shard owns its output columns — no cross-shard composition);
# row-parallel tensors split K (per-shard partial products sum, the psum
# composition `kernels/ops._sharded_spmm` performs).  The LM head is
# vocab-split (col).  Tensors with no rule (router, SSM decay/mix
# vectors, norms) stay replicated.

PACKED_COL = {
    ("attn", "wq"), ("attn", "wk"), ("attn", "wv"),
    ("mlp", "w_gate"), ("mlp", "w_up"),
    ("moe", "w_gate"), ("moe", "w_up"),
    ("mamba", "in_proj"), ("mamba", "dt_proj"),
    ("rwkv", "w_r"), ("rwkv", "w_k"), ("rwkv", "w_v"), ("rwkv", "w_g"),
    ("rwkv_cm", "cm_k"),
}
PACKED_ROW = {
    ("attn", "wo"),
    ("mlp", "w_down"),
    ("moe", "w_down"),
    ("mamba", "out_proj"), ("mamba", "x_proj"),
    ("rwkv", "w_o"),
    ("rwkv_cm", "cm_v"),
}


def packed_mode(comp: str, name: str) -> Optional[str]:
    """Shard mode for a packed tensor: "col", "row", or None (replicate)."""
    if (comp, name) in PACKED_COL:
        return "col"
    if (comp, name) in PACKED_ROW:
        return "row"
    return None


def bitmap_sharded(bw: Optional[BitmapWeight], mesh) -> bool:
    """Whether this ``BitmapWeight``'s explicit shard axis lines up with
    the mesh's live model axis (the single predicate the spec builder
    and the shard_map gather share)."""
    return (bw is not None and bw.shard is not None
            and "model" in mesh.shape
            and mesh.shape["model"] == bw.shard[1] > 1)


def bitmap_specs(bw: Optional[BitmapWeight], mesh) -> Any:
    """A ``BitmapWeight`` of ``PartitionSpec`` leaves mirroring ``bw``:
    'model' on the explicit shard axis when it matches the mesh, else
    fully replicated.  ``dataclasses.replace`` keeps the static fields
    (shape/block/shard), so the spec tree has the same treedef as the
    array tree — valid for ``device_put`` and ``shard_map`` in_specs."""
    if bw is None:
        return None
    live = bitmap_sharded(bw, mesh)

    def spec(leaf, tile_nd):
        if leaf is None:
            return None
        if not live:
            return P()
        axes: list = [None] * leaf.ndim
        axes[leaf.ndim - tile_nd - 1] = "model"
        return P(*axes)

    return dataclasses.replace(
        bw,
        packed_bits=spec(bw.packed_bits, _TILE_ND["packed_bits"]),
        values=spec(bw.values, _TILE_ND["values"]),
        row_start=spec(bw.row_start, _TILE_ND["row_start"]),
        dense_cache=spec(bw.dense_cache, _TILE_ND["dense_cache"]))


def packed_specs(tree: Any, mesh) -> Any:
    """Specs for a packed block tree (``PackedModel.blocks``): per-leaf
    ``bitmap_specs``, Nones preserved."""
    return jax.tree.map(lambda bw: bitmap_specs(bw, mesh), tree,
                        is_leaf=lambda x: isinstance(x, BitmapWeight))


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
