"""Fault-tolerant distributed training driver.

Production posture (scaled down to whatever mesh the live devices allow):
  * pjit train step with full param/opt sharding (launch/sharding.py);
  * step-atomic checkpoints every ``ckpt_every`` with async write-behind,
    auto-resume from the latest committed step (crash/preemption recovery);
  * deterministic step-indexed data (restart-safe, no replay bookkeeping);
  * optional global-L1 pruning + masked sparse training (the paper's
    technique as a training feature);
  * per-step wall/loss logging with a straggler watchdog that flags steps
    slower than ``straggler_factor``× the trailing median (on real clusters
    this feeds the controller that evicts slow hosts).

Run (CPU example):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch import sharding as shd
from repro.launch.mesh import make_elastic_mesh
from repro.launch.steps import build_train_step
from repro.models.model import init_params
from repro.sparse.pruning import global_l1_prune, sparsity_of
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig
from repro.train import optimizer as opt_lib


def train(arch: str, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str | None = None, ckpt_every: int = 20,
          sparsity: float = 0.0, lr: float = 3e-4, model_parallel: int = 1,
          straggler_factor: float = 3.0, log_every: int = 1,
          seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_elastic_mesh(model_parallel)
    opt_cfg = OptConfig(lr=lr, total_steps=max(steps, 2),
                        warmup_steps=max(steps // 10, 1))

    params = init_params(jax.random.PRNGKey(seed), cfg)
    masks = None
    if sparsity > 0:
        params = global_l1_prune(params, sparsity)
        masks = jax.tree.map(lambda p: (p != 0).astype(p.dtype), params)
        print(f"pruned to {sparsity_of(params):.2%} sparsity")
    opt_state = opt_lib.init(params)

    start_step = 0
    if ckpt_dir:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            print(f"resuming from checkpoint step {latest}")
            state = ckpt.restore(ckpt_dir, latest,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest

    pspecs = shd.named(mesh, shd.param_specs(cfg, mesh))
    ospecs = shd.named(mesh, shd.opt_specs(cfg, mesh))
    params = jax.device_put(params, pspecs)
    opt_state = jax.device_put(opt_state, ospecs)

    step_fn = build_train_step(cfg, opt_cfg, prune_masks=masks)
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        data_cfg = DataConfig(global_batch=batch, seq_len=seq, seed=seed)
        loader = Prefetcher(cfg, data_cfg, start_step=start_step)
        times: list = []
        losses: list = []
        pending_ckpt = None
        try:
            for _ in range(steps - start_step):
                step_idx, batch_np = next(loader)
                t0 = time.time()
                batch_dev = jax.tree.map(jax.numpy.asarray, batch_np)
                params, opt_state, metrics = jit_step(params, opt_state,
                                                      batch_dev)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                times.append(dt)
                losses.append(loss)
                if len(times) >= 5:
                    med = statistics.median(times[-20:])
                    if dt > straggler_factor * med:
                        print(f"[straggler] step {step_idx}: {dt:.2f}s vs "
                              f"median {med:.2f}s", flush=True)
                if step_idx % log_every == 0:
                    print(f"step {step_idx:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms",
                          flush=True)
                if ckpt_dir and (step_idx + 1) % ckpt_every == 0:
                    if pending_ckpt is not None:
                        pending_ckpt.join()
                    pending_ckpt = ckpt.save(
                        ckpt_dir, step_idx + 1,
                        {"params": params, "opt": opt_state}, async_=True)
        finally:
            loader.close()
            if pending_ckpt is not None:
                pending_ckpt.join()
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    res = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, sparsity=args.sparsity,
                lr=args.lr, model_parallel=args.model_parallel)
    print(f"final loss: {res['final_loss']:.4f}")


if __name__ == "__main__":
    main()
