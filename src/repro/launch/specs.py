"""ShapeDtypeStruct stand-ins for every model input (allocation-free).

``input_specs(cfg, shape)`` returns the exact aval pytree the corresponding
step function is lowered with — weak-type-correct, shardable, no device
allocation.  Modality frontends are stubs per the assignment: [audio] gets
precomputed frame embeddings, [vlm] gets patch embeddings prepended.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import ShapeCfg
from repro.models.config import ModelConfig
from repro.models.model import cache_structs


def train_batch_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    i32 = jnp.int32
    if cfg.frontend == "frames":
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cfg.frontend == "patches":
        fl = cfg.frontend_len
        return {
            "embeds": jax.ShapeDtypeStruct((b, fl, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct((b, s - fl), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "targets": jax.ShapeDtypeStruct((b, s), i32),
    }


def decode_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict:
    """(cache, tokens/embeds, pos) avals for one decode step with a
    seq_len-deep cache."""
    b = shape.global_batch
    dt = jnp.dtype(cfg.compute_dtype)
    out = {
        "cache": cache_structs(cfg, b, shape.seq_len),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.frontend == "frames":
        out["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict:
    if shape.kind in ("train", "prefill"):
        return train_batch_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
