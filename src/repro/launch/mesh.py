"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax init,
while smoke tests and benches must see one device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(model_parallel: int = 16):
    """Build the largest (data, model) mesh the *live* device set allows.

    Elastic-restart path: after losing a host, the job re-derives the DP
    extent from the surviving device count and restores the checkpoint onto
    the smaller mesh (checkpoint.restore reshards).
    """
    n = len(jax.devices())
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
