"""Compiled-HLO analysis: collective traffic + roofline terms.

``collective_bytes`` parses the post-SPMD optimized HLO text and sums the
result-shape bytes of every collective op (per-device view).  Wire-traffic
factors (ring algorithms, large-group limit): all-reduce counts 2×, the
rest 1×.  ``roofline`` turns (flops, hbm bytes, collective bytes) into the
three per-device time terms for TPU v5e-class hardware.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch.hlo_shapes import shape_bytes

# hardware constants (per chip) — TPU v5e class, from the assignment
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~ per-direction usable)

_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind result-byte totals + wire-adjusted sum."""
    out: Dict[str, float] = {k: 0.0 for k in _WIRE_FACTOR}
    counts: Dict[str, int] = {k: 0 for k in _WIRE_FACTOR}
    for m in _COLL_RE.finditer(hlo_text):
        shp, kind = m.group(1), m.group(2)
        # -done ops repeat the -start result; count starts & sync forms only
        before = hlo_text[max(0, m.start() - 0):m.end()]
        if "-done(" in before[-60:]:
            continue
        out[kind] += shape_bytes(shp)
        counts[kind] += 1
    wire = sum(out[k] * _WIRE_FACTOR[k] for k in out)
    return {**{f"{k}_bytes": v for k, v in out.items()},
            **{f"{k}_count": c for k, c in counts.items()},
            "wire_bytes": wire}


def roofline(flops: float, hbm_bytes: float, wire_bytes: float,
             num_links: int = 4) -> Dict[str, float]:
    """Three per-device roofline time terms (seconds) + the bottleneck."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_collective = wire_bytes / (ICI_BW * num_links)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    bound = max(terms, key=terms.get)
    t_max = terms[bound]
    t_sum = t_compute + t_memory + t_collective
    return {
        **terms,
        "bottleneck": bound.replace("_s", ""),
        # fraction of the ideal overlapped step this term would allow
        "roofline_fraction_overlap": t_max / t_sum if t_sum else 0.0,
        "step_time_overlapped_s": t_max,
    }
