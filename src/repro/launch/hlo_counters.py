"""While-aware HLO counters: FLOPs / bytes / collective traffic.

``compiled.cost_analysis()`` counts every while-loop *body once*, which
under-counts scanned layer stacks (and chunked attention / SSM scans /
chunked losses) by their trip counts.  This analyzer parses the optimized
HLO text, extracts per-while ``known_trip_count`` from ``backend_config``
(falling back to the loop-condition constant), propagates multipliers down
the computation call graph, and accumulates:

* FLOPs       — 2·prod(result)·prod(contracted) per ``dot`` (matmuls are
                >99 % of model FLOPs; elementwise ignored, as in MFU math);
* bytes       — per instruction: operands + outputs at fusion granularity
                (the HloCostAnalysis HBM-traffic model), with the standard
                special cases for (dynamic-)slice/update/gather/scatter so
                scan xs-slicing does not charge the whole stacked buffer;
* collectives — result bytes per kind, ×2 wire factor for all-reduce.

Validated in tests against analytic FLOP counts of known GEMM/scan
programs.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional

from repro.launch.hlo_shapes import (shape_bytes as _shape_bytes,
                                     shape_dims as _shape_dims)

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+"
    r"\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_WIRE_FACTOR = {k: (2.0 if k == "all-reduce" else 1.0) for k in COLLECTIVES}

_SKIP_BYTES = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "custom-call",
}


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str          # args + attrs (everything after the opening paren)
    is_root: bool = False

    @property
    def args(self) -> List[str]:
        depth, i0 = 1, 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return re.findall(r"%([\w.\-]+)", self.rest[:i])
        return re.findall(r"%([\w.\-]+)", self.rest)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-{}]+)", self.rest)
        return m.group(1) if m else None


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(*m.groups(),
                                    is_root="ROOT" in line[:12]))
    return comps


def _entry_name(text: str, comps) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps))


def _trip_count(instr: Instr, comps) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"', instr.rest)
    if m:
        return int(m.group(1))
    cond = instr.attr("condition")
    if cond and cond in comps:
        consts = [int(c) for i in comps[cond]
                  for c in re.findall(r"constant\((\d+)\)", i.rest)]
        if consts:
            return max(consts)
    return 1


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    res = _shape_dims(instr.shape)
    if not res:
        return 0.0
    out_elems = math.prod(res[0][1])
    lhs = symtab.get(instr.args[0] if instr.args else "", "")
    lhs_dims = _shape_dims(lhs)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if not lhs_dims or not m:
        return 2.0 * out_elems  # degenerate fallback
    dims = lhs_dims[0][1]
    contracted = math.prod(dims[int(i)] for i in m.group(1).split(",") if i)
    return 2.0 * out_elems * contracted


def _instr_bytes(instr: Instr, symtab: Dict[str, str]) -> int:
    op = instr.op
    out_b = _shape_bytes(instr.shape)
    if op in _SKIP_BYTES:
        return 0
    args = instr.args
    if op in ("slice", "dynamic-slice"):
        return 2 * out_b
    if op == "dynamic-update-slice":
        upd = symtab.get(args[1], "") if len(args) > 1 else ""
        return 2 * _shape_bytes(upd)
    if op == "gather":
        idx = symtab.get(args[1], "") if len(args) > 1 else ""
        return 2 * out_b + _shape_bytes(idx)
    if op == "scatter":
        upd = symtab.get(args[-1], "") if args else ""
        return 2 * _shape_bytes(upd) + out_b
    in_b = sum(_shape_bytes(symtab.get(a, "")) for a in args)
    return in_b + out_b


def _param_index(instr: Instr) -> int:
    m = re.match(r"(\d+)", instr.rest)
    return int(m.group(1)) if m else 0


def _fusion_bytes(instr: Instr, symtab: Dict[str, str],
                  comps: Dict[str, List[Instr]]) -> int:
    """HBM traffic of one fusion: analyze the called computation so that
    parameters consumed only through (dynamic-)slices/gathers are charged
    at their *used* size, and an in-place dynamic-update-slice root is
    charged at the update size — matching HloCostAnalysis semantics.
    Without this, scan bodies slicing stacked layer params/residuals get
    charged the whole stacked buffer every iteration (~20× inflation)."""
    called = instr.attr("calls")
    body = comps.get(called)
    if body is None:
        return _shape_bytes(instr.shape) + sum(
            _shape_bytes(symtab.get(a, "")) for a in instr.args)
    body_syms = {i.name: i.shape for i in body}
    body_map = {i.name: i for i in body}
    views = {"bitcast", "copy", "convert", "reshape", "transpose"}

    def resolve(name: str) -> str:
        seen = set()
        while (name in body_map and body_map[name].op in views
               and body_map[name].args and name not in seen):
            seen.add(name)
            name = body_map[name].args[0]
        return name

    params = sorted((i for i in body if i.op == "parameter"),
                    key=_param_index)
    uses: Dict[str, List[Instr]] = {p.name: [] for p in params}
    for i in body:
        if i.op == "parameter" or i.op in views:
            continue
        for a in i.args:
            r = resolve(a)
            if r in uses:
                uses[r].append(i)

    total = 0
    for p in params:
        u = uses[p.name]
        full = _shape_bytes(p.shape)
        if u and all(x.op in ("dynamic-slice", "slice", "gather")
                     and x.args and resolve(x.args[0]) == p.name
                     for x in u):
            total += min(full, sum(_shape_bytes(x.shape) for x in u))
        elif u and all(x.op == "dynamic-update-slice"
                       and x.args and resolve(x.args[0]) == p.name
                       for x in u):
            total += min(full, sum(
                _shape_bytes(body_syms.get(resolve(x.args[1]), ""))
                for x in u if len(x.args) > 1))
        else:
            total += full

    # output: an in-place DUS root writes only the update region
    out_b = _shape_bytes(instr.shape)
    roots = [i for i in body if i.is_root] or body[-1:]
    if roots:
        r = body_map.get(resolve(roots[0].name))
        if r is not None and r.op == "dynamic-update-slice" and len(
                r.args) > 1:
            upd = _shape_bytes(body_syms.get(resolve(r.args[1]), ""))
            out_b = min(out_b, upd or out_b)
    return total + out_b


def analyze(text: str) -> Dict[str, float]:
    comps = parse_computations(text)
    entry = _entry_name(text, comps)
    symtabs = {c: {i.name: i.shape for i in instrs}
               for c, instrs in comps.items()}

    # propagate execution multipliers through the call graph
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    fusion_called = set()
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        for instr in comps[comp]:
            targets = []
            if instr.op == "while":
                trip = _trip_count(instr, comps)
                body, cond = instr.attr("body"), instr.attr("condition")
                if body in comps:
                    targets.append((body, trip))
                if cond in comps:
                    targets.append((cond, trip + 1))
            elif instr.op == "fusion":
                tgt = instr.attr("calls")
                if tgt in comps:
                    fusion_called.add(tgt)
                    targets.append((tgt, 1))
            elif instr.op in ("call", "async-start"):
                tgt = instr.attr("to_apply")
                if tgt in comps:
                    targets.append((tgt, 1))
            elif instr.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    tgt = instr.attr(key)
                    if tgt in comps:
                        targets.append((tgt, 1))
                for tgt in re.findall(r"branch_computations=\{([^}]*)\}",
                                      instr.rest):
                    for t in re.findall(r"%([\w.\-]+)", tgt):
                        if t in comps:
                            targets.append((t, 1))
            for tgt, k in targets:
                mult[tgt] += mult[comp] * k
                if tgt not in seen:
                    seen.add(tgt)
                    order.append(tgt)

    flops = 0.0
    bytes_ = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_n: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        st = symtabs[comp]
        in_fusion = comp in fusion_called
        for instr in instrs:
            base = instr.op.replace("-start", "")
            if base in ("dot", "convolution"):
                flops += m * _dot_flops(instr, st)
            if instr.op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                b = _shape_bytes(instr.shape)
                coll[base] += m * b
                coll_n[base] += m
            if not in_fusion:
                if instr.op == "fusion":
                    bytes_ += m * _fusion_bytes(instr, st, comps)
                else:
                    bytes_ += m * _instr_bytes(instr, st)

    wire = sum(coll[k] * _WIRE_FACTOR[k] for k in coll)
    return {
        "flops": flops,
        "bytes": bytes_,
        "wire_bytes": wire,
        **{f"{k}_bytes": v for k, v in coll.items() if v},
        **{f"{k}_count": v for k, v in coll_n.items() if v},
    }


def analyze_compiled(compiled) -> Dict[str, float]:
    return analyze(compiled.as_text())
