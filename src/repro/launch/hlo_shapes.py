"""Shared HLO shape vocabulary: dtype widths + shape-text parsing.

``launch/hlo_counters.py`` (the while-aware FLOP/byte analyzer) and
``launch/hlo_analysis.py`` (collective traffic + roofline terms) each
carried their own copy of the XLA dtype-width table and the
``f32[128,64]``-style shape regex; the copies had already drifted (the
analysis table was missing the fnuz f8 variants and u1/s1).  This module
is the one definition both import.
"""
from __future__ import annotations

import math
import re
from typing import List, Tuple

#: bytes per element for every XLA primitive dtype that can appear in a
#: printed HLO shape (sub-byte types round up to one byte, matching how
#: HloCostAnalysis charges them)
DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "u1": 1, "s1": 1,
}

#: one array shape inside HLO text: ``f32[8,128]`` / ``pred[]`` — tuple
#: shapes match once per element
ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    """Every ``(dtype, dims)`` array shape in ``text`` (tuple shapes
    yield one entry per element; non-dtype brackets are skipped)."""
    out = []
    for dt, dims in ARRAY_RE.findall(text):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(text: str) -> int:
    """Total byte size of every array shape in ``text``."""
    total = 0
    for dt, dims in shape_dims(text):
        total += DTYPE_BYTES[dt] * math.prod(dims)
    return total
