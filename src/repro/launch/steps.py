"""Step-function builders: train, prefill, serve (decode).

These close over the static ModelConfig so the jitted callables take only
array pytrees — the exact functions the dry-run lowers and the drivers run.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_nocheck
from repro.launch.sharding import bitmap_sharded, bitmap_specs, packed_specs
from repro.models.config import ModelConfig
from repro.models.model import (decode_step, forward, lm_head_weight,
                                lm_loss, loss_fn, prefill_hidden)
from repro.sparse.format import BitmapWeight, gather_bitmap
from repro.train import optimizer as opt_lib


def build_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
                     prune_masks: Optional[Dict] = None,
                     accum_steps: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``prune_masks`` (same tree as params, 0/1) keeps pruned weights at zero
    through training — the paper's sparse-model regime as a first-class
    training feature (masked-gradient sparse training).

    ``accum_steps`` > 1 splits the batch into microbatches scanned
    sequentially with gradient accumulation — the activation working set
    (the dominant train-cell memory term, §Perf) shrinks ~linearly while
    the DP gradient all-reduce still happens once per step.  Token-mean
    loss with equal microbatch sizes makes this *numerically identical* to
    the single-pass step (tests/test_accum.py).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + tuple(x.shape[1:])), batch)

            def mb(carry, mbatch):
                gsum, lsum, csum = carry
                (_, m), g = grads_of(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + m["loss"] * m["tokens"],
                        csum + m["tokens"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, csum), _ = jax.lax.scan(
                mb, (zeros, jnp.float32(0), jnp.float32(0)), micro)
            # microbatches carry equal token counts -> mean of means is
            # exact; grads averaged the same way
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / jnp.maximum(csum, 1)
            metrics = {"loss": loss, "tokens": csum}
        if prune_masks is not None:
            grads = jax.tree.map(lambda g, m: g * m, grads, prune_masks)
        new_params, new_opt, opt_metrics = opt_lib.update(
            params, grads, opt_state, opt_cfg)
        if prune_masks is not None:
            new_params = jax.tree.map(lambda p, m: p * m, new_params,
                                      prune_masks)
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def build_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg)
        return metrics
    return eval_step


def build_prefill_logits_step(cfg: ModelConfig) -> Callable:
    """Forward over the full prompt; returns last-position logits.

    The *dry-run* prefill cell: it measures the forward compute and
    intentionally omits KV export (see DESIGN.md).  The serving engine's
    cache-writing chunked prefill is ``build_prefill_step`` below.
    """

    def prefill_logits_step(params, batch):
        hidden = forward(params, cfg, tokens=batch.get("tokens"),
                         embeds=batch.get("embeds"))
        w = lm_head_weight(params, cfg).astype(hidden.dtype)
        logits = (hidden[:, -1] @ w).astype(jnp.float32)
        return logits

    return prefill_logits_step


def build_prefill_step(cfg: ModelConfig, impl: Optional[str] = None
                       ) -> Callable:
    """One chunked-prefill call for the serving engine:
    (params, cache, tokens, pos, lens) -> (hidden, new_cache).

    ``tokens`` is a (B, C) chunk batch — one C-token slice of prompt per
    batch slot, assembled by ``repro.serve.prefill.PrefillPlanner`` from
    however many admitted requests are mid-prefill.  ``pos`` ((B,) int32)
    is each slot's chunk start position and ``lens`` ((B,) int32) its
    valid token count this call (0 = padding lane: the slot writes
    nothing).  The call writes C KV lines per participating slot —
    causal within the chunk, attending to the slot's existing cache — so
    a prompt is ingested in ``ceil((len(prompt) - 1) / C)`` calls
    instead of ``len(prompt) - 1`` full-batch decode steps, with every
    projection dispatched at M = C through the packed
    ``matmul_or_bitmap`` path (``packed`` / ``lm_weight`` mirror
    ``build_serve_step``; there is no LM head here — the first sampled
    token comes from the first real decode step after prefill).

    ``page_tables`` routes the KV writes through the paged layout; the
    engine bulk-maps the chunk's pages (``PagedKVCache.ensure_range``)
    before the call.
    """

    def prefill_step(params, cache, tokens, pos, lens, embeds=None,
                     packed=None, page_tables=None):
        return prefill_hidden(params, cache, cfg, tokens, pos, lens,
                              embeds=embeds, packed=packed, impl=impl,
                              page_tables=page_tables)

    return prefill_step


def build_serve_step(cfg: ModelConfig, impl: Optional[str] = None,
                     top_k: int = 0) -> Callable:
    """One decode step + head: (params, cache, tokens/embeds, pos)
    -> (next_token, logits, new_cache).

    ``pos`` may be a scalar (classic lock-step decode) or a (B,) vector of
    per-slot positions (continuous batching).  ``lm_weight`` (a
    ``BitmapWeight``) routes the LM head through the bitmap-compressed
    ``kernels/ops.bitmap_spmm`` path and ``packed`` (the block tree from
    ``repro.serve.packed.pack_model``) does the same for every attention
    and MLP projection; ``impl`` pins the kernel dispatch
    ("xla" | "pallas" | "pallas_interpret", default backend-chosen).

    ``page_tables`` (``{bname: (B, page_slots) int32}``, see
    ``repro.serve.paging``) routes the KV cache through the paged layout;
    omitted, the contiguous per-slot cache is unchanged.

    ``embed_rng`` (frames frontend): a PRNG key the step derives the
    per-step frame embeddings from on device — no host round-trip in the
    decode loop.

    Sampling: with ``sample_keys`` ((B, 2) uint32, one key per slot) and
    ``temperature`` ((B,) f32) the head samples from
    ``softmax(logits / T)``; slots with T == 0 stay exactly greedy, so
    the default is unchanged.  ``top_ks`` ((B,) int32) truncates each
    slot's sample to its own top-k via a masked threshold (0 = no
    truncation) — per-request top_k with one jit signature; the builder's
    static ``top_k`` is only a fallback default when no vector is passed.
    Keys are folded with the slot position, so a request's sample at
    position p depends only on (its seed, p) — deterministic under
    continuous batching regardless of scheduling.
    """

    def serve_step(params, cache, tokens, pos, embeds=None, lm_weight=None,
                   packed=None, embed_rng=None, sample_keys=None,
                   temperature=None, top_ks=None, page_tables=None):
        if embed_rng is not None and embeds is None:
            b = pos.shape[0] if jnp.ndim(pos) else 1
            embeds = jax.random.normal(embed_rng, (b, 1, cfg.d_model),
                                       jnp.float32)
        logits, new_cache = decode_step(params, cache, cfg, tokens, pos,
                                        embeds=embeds, lm_weight=lm_weight,
                                        packed=packed, lm_impl=impl,
                                        page_tables=page_tables)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if sample_keys is not None and temperature is not None:
            posv = jnp.broadcast_to(pos, next_tok.shape)
            keys = jax.vmap(jax.random.fold_in)(sample_keys, posv)
            scaled = logits.astype(jnp.float32) / jnp.maximum(
                temperature, 1e-6)[:, None]
            if top_ks is not None:
                # per-slot masked top-k: each row keeps values >= its own
                # k-th largest (same tie behaviour as lax.top_k's static
                # truncation); k <= 0 rows keep the full distribution
                v = scaled.shape[-1]
                desc = -jnp.sort(-scaled, axis=-1)
                idx = jnp.clip(top_ks - 1, 0, v - 1)[:, None]
                kth = jnp.take_along_axis(desc, idx, axis=1)
                scaled = jnp.where((top_ks[:, None] > 0) & (scaled < kth),
                                   -jnp.inf, scaled)
            elif top_k > 0:
                # every slot at the engine default: the static lax.top_k
                # threshold (O(V·k)) beats the per-slot full-vocab sort
                kth = jax.lax.top_k(scaled, top_k)[0][:, -1:]
                scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
            sampled = jax.vmap(jax.random.categorical)(keys, scaled)
            next_tok = jnp.where(temperature > 0,
                                 sampled.astype(jnp.int32), next_tok)
        return next_tok, logits, new_cache

    return serve_step


# ---------------------------------------------------------------- SPMD ----
# Sharded serving: the decode/prefill steps above, run under shard_map on
# the engine's elastic (data, model) mesh.  Packed ``BitmapWeight`` leaves
# arrive model-sharded along their explicit shard axis (format.shard_bitmap
# layout — the per-device HBM cut), paged KV pools arrive data-sharded
# along the pages axis (paging.PagedKVCache(shards=...) keeps every slot's
# pages shard-local).  The body is gather-then-compute: sharded operands
# are all-gathered device-side, the *unchanged* base step runs, and each
# device keeps its own slice of the new cache — so the numerics (and the
# sampled tokens) are bit-identical to the single-device step by
# construction, while the weights and pool pages each device *stores*
# are 1/shard of the stack.


def _replicated(tree) -> object:
    """A matching tree of fully-replicated PartitionSpecs (None stays
    None, so optional step kwargs spec out naturally)."""
    return jax.tree.map(lambda _: P(), tree)


def _cache_specs(cache, data_pools: frozenset, ndata: int) -> Dict:
    """Specs for the serve cache dict: paged k/v pools shard their pages
    axis (axis 1, after the period stack) over "data"; everything else —
    contiguous caches, recurrent state, non-pool blocks — replicates."""

    def spec(bname, key, leaf):
        if ndata > 1 and bname in data_pools and key in ("k", "v"):
            axes: list = [None] * leaf.ndim
            axes[1] = "data"
            return P(*axes)
        return P()

    return {b: {k: spec(b, k, v) for k, v in leafd.items()}
            for b, leafd in cache.items()}


def _gather_cache(cache, data_pools: frozenset, ndata: int) -> Dict:
    """Inside the shard_map body: reassemble the full page pools from
    the per-device chunks (page ids in the tables are global)."""
    if ndata <= 1 or not data_pools:
        return cache
    return {b: ({k: (jax.lax.all_gather(v, "data", axis=1, tiled=True)
                     if k in ("k", "v") else v)
                 for k, v in leafd.items()}
                if b in data_pools else leafd)
            for b, leafd in cache.items()}


def _slice_cache(cache, data_pools: frozenset, ndata: int) -> Dict:
    """Inverse of ``_gather_cache``: each device keeps its own shard's
    contiguous page chunk of the written pool.  The paged allocator maps
    every slot's pages (and its trash writes) inside its own shard's id
    range, so the kept chunk holds exactly this device's slots' lines."""
    if ndata <= 1 or not data_pools:
        return cache
    idx = jax.lax.axis_index("data")

    def keep(leaf):
        local = leaf.shape[1] // ndata
        return jax.lax.dynamic_slice_in_dim(leaf, idx * local, local,
                                            axis=1)

    return {b: ({k: (keep(v) if k in ("k", "v") else v)
                 for k, v in leafd.items()}
                if b in data_pools else leafd)
            for b, leafd in cache.items()}


def _gather_packed(tree, mesh):
    """All-gather every model-sharded ``BitmapWeight`` in a packed block
    tree back to its unsharded layout (replicated leaves pass through)."""
    if tree is None:
        return None
    return jax.tree.map(
        lambda bw: (gather_bitmap(bw, "model")
                    if bitmap_sharded(bw, mesh) else bw),
        tree, is_leaf=lambda x: isinstance(x, BitmapWeight))


def build_serve_step_spmd(cfg: ModelConfig, mesh,
                          impl: Optional[str] = None, top_k: int = 0,
                          data_pools: Sequence[str] = ()) -> Callable:
    """``build_serve_step`` under shard_map on ``mesh`` — same signature,
    same numerics, sharded storage.

    ``data_pools``: names of the paged-cache pools whose pages axis is
    sharded over the mesh "data" axis (the engine passes its
    ``PagedKVCache`` pool names when ``kv.shards`` matches the data
    extent; empty = cache fully replicated).  PartitionSpecs are derived
    from the actual arguments at trace time: ``BitmapWeight`` leaves via
    ``sharding.bitmap_specs`` (their explicit shard axis over "model"),
    pool k/v leaves over "data", everything else replicated.
    """
    base = build_serve_step(cfg, impl=impl, top_k=top_k)
    pools = frozenset(data_pools)
    ndata = int(mesh.shape.get("data", 1))

    def serve_step(params, cache, tokens, pos, embeds=None, lm_weight=None,
                   packed=None, embed_rng=None, sample_keys=None,
                   temperature=None, top_ks=None, page_tables=None):
        args = (params, cache, tokens, pos, embeds, lm_weight, packed,
                embed_rng, sample_keys, temperature, top_ks, page_tables)
        cspecs = _cache_specs(cache, pools, ndata)
        in_specs = (_replicated(params), cspecs, _replicated(tokens),
                    _replicated(pos), _replicated(embeds),
                    bitmap_specs(lm_weight, mesh),
                    packed_specs(packed, mesh), _replicated(embed_rng),
                    _replicated(sample_keys), _replicated(temperature),
                    _replicated(top_ks), _replicated(page_tables))

        def body(params, cache, tokens, pos, embeds, lm_weight, packed,
                 embed_rng, sample_keys, temperature, top_ks, page_tables):
            lm = (gather_bitmap(lm_weight, "model")
                  if bitmap_sharded(lm_weight, mesh) else lm_weight)
            nxt, logits, new_cache = base(
                params, _gather_cache(cache, pools, ndata), tokens, pos,
                embeds=embeds, lm_weight=lm,
                packed=_gather_packed(packed, mesh), embed_rng=embed_rng,
                sample_keys=sample_keys, temperature=temperature,
                top_ks=top_ks, page_tables=page_tables)
            return nxt, logits, _slice_cache(new_cache, pools, ndata)

        return shard_map_nocheck(body, mesh, in_specs,
                                 (P(), P(), cspecs))(*args)

    return serve_step


def build_prefill_step_spmd(cfg: ModelConfig, mesh,
                            impl: Optional[str] = None,
                            data_pools: Sequence[str] = ()) -> Callable:
    """``build_prefill_step`` under shard_map on ``mesh`` — the chunked
    prefill analogue of ``build_serve_step_spmd`` (same gather-then-
    compute body, same spec derivation, no head)."""
    base = build_prefill_step(cfg, impl=impl)
    pools = frozenset(data_pools)
    ndata = int(mesh.shape.get("data", 1))

    def prefill_step(params, cache, tokens, pos, lens, embeds=None,
                     packed=None, page_tables=None):
        args = (params, cache, tokens, pos, lens, embeds, packed,
                page_tables)
        cspecs = _cache_specs(cache, pools, ndata)
        in_specs = (_replicated(params), cspecs, _replicated(tokens),
                    _replicated(pos), _replicated(lens),
                    _replicated(embeds), packed_specs(packed, mesh),
                    _replicated(page_tables))

        def body(params, cache, tokens, pos, lens, embeds, packed,
                 page_tables):
            hidden, new_cache = base(
                params, _gather_cache(cache, pools, ndata), tokens, pos,
                lens, embeds=embeds, packed=_gather_packed(packed, mesh),
                page_tables=page_tables)
            return hidden, _slice_cache(new_cache, pools, ndata)

        return shard_map_nocheck(body, mesh, in_specs,
                                 (P(), cspecs))(*args)

    return prefill_step
