import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real step
function with ShapeDtypeStruct inputs (no allocation), compiles, and
records memory_analysis / cost_analysis / collective traffic to
``results/dryrun/<arch>__<shape>__<mesh>.json`` for the roofline report.

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--both]
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (SHAPES, cells, get_config,       # noqa: E402
                           shape_supported)
from repro.launch import sharding as shd                    # noqa: E402
from repro.launch.hlo_counters import analyze as hlo_analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_tag  # noqa: E402
from repro.launch.specs import (decode_input_specs,         # noqa: E402
                                train_batch_specs)
from repro.launch.steps import (build_prefill_logits_step,  # noqa: E402
                                build_serve_step, build_train_step)
from repro.models.model import param_structs                # noqa: E402
from repro.train.optimizer import OptConfig                 # noqa: E402


def _opt_structs(cfg):
    ps = param_structs(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32)
    return {"m": jax.tree.map(f32, ps), "v": jax.tree.map(f32, ps),
            "step": jax.ShapeDtypeStruct((), jax.numpy.int32)}


def lower_cell(arch: str, shape_name: str, mesh, *,
               sparse_override=None, accum_steps: int = 1):
    """Build and lower one cell; returns (lowered, meta)."""
    cfg = get_config(arch)
    if sparse_override is not None:
        cfg = sparse_override(cfg)
    shape = SHAPES[shape_name]
    pspecs = shd.param_specs(cfg, mesh, serve=shape.kind == "decode")
    psh = shd.named(mesh, pspecs)
    params = param_structs(cfg)

    if shape.kind == "train":
        step = build_train_step(cfg, OptConfig(), accum_steps=accum_steps)
        batch = train_batch_specs(cfg, shape)
        bspec_fn = shd.batch_specs(cfg, mesh, shape.global_batch)
        bsh = {k: NamedSharding(mesh, bspec_fn(k)) for k in batch}
        osh = shd.named(mesh, shd.opt_specs(cfg, mesh))
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1),
            ).lower(params, _opt_structs(cfg), batch)
    elif shape.kind == "prefill":
        step = build_prefill_logits_step(cfg)
        batch = train_batch_specs(cfg, shape)
        batch.pop("targets")
        bspec_fn = shd.batch_specs(cfg, mesh, shape.global_batch)
        bsh = {k: NamedSharding(mesh, bspec_fn(k)) for k in batch}
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(psh, bsh), out_shardings=None,
            ).lower(params, batch)
    else:  # decode
        # NOTE (§Perf iter 5, refuted-on-CPU): storing serving weights in
        # bf16 *increases* the CPU-lowered byte count because XLA:CPU has
        # no native bf16 and re-expands every bf16 dot through f32
        # converts; on TPU bf16 storage is a strict win. Weight-store
        # dtype is therefore excluded from the CPU dry-run A/B and the
        # analytic weight term assumes 2 B/weight (EXPERIMENTS §Perf).
        step = build_serve_step(cfg)
        specs = decode_input_specs(cfg, shape)
        shard_seq = shape.global_batch == 1
        csh = shd.named(mesh, shd.cache_specs(
            cfg, mesh, shape.global_batch, shape.seq_len,
            shard_seq=shard_seq))
        bspec_fn = shd.batch_specs(cfg, mesh, shape.global_batch)
        tok_sh = NamedSharding(mesh, bspec_fn("tokens"))
        emb_sh = NamedSharding(mesh, bspec_fn("embeds"))
        pos_sh = NamedSharding(mesh, P())
        with mesh:
            if "embeds" in specs:
                lowered = jax.jit(
                    lambda p, c, e, pos: step(p, c, None, pos, embeds=e),
                    in_shardings=(psh, csh, emb_sh, pos_sh),
                    out_shardings=None, donate_argnums=(1,),
                ).lower(params, specs["cache"], specs["embeds"],
                        specs["pos"])
            else:
                lowered = jax.jit(
                    step, in_shardings=(psh, csh, tok_sh, pos_sh),
                    out_shardings=None, donate_argnums=(1,),
                ).lower(params, specs["cache"], specs["tokens"],
                        specs["pos"])
    return lowered, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun", verbose: bool = True,
             sparse_override=None, tag: str = "",
             accum_steps: int = 1) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, cfg = lower_cell(arch, shape_name, mesh,
                              sparse_override=sparse_override,
                              accum_steps=accum_steps)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": repr(e)}
    t0 = time.time()
    counters = hlo_analyze(compiled.as_text())
    t_analyze = time.time() - t0

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag(mesh),
        "multi_pod": multi_pod,
        "num_devices": int(mesh.devices.size),
        # while-aware per-device counters (see hlo_counters.py)
        "flops_per_device": counters["flops"],
        "hbm_bytes_per_device": counters["bytes"],
        "collectives": {k: v for k, v in counters.items()
                        if k not in ("flops", "bytes")},
        # raw XLA cost analysis kept for reference (while bodies ×1)
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))
                              and not k.startswith("utilization")},
        "memory_analysis": mem_d,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_tag(mesh)}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        print(f"[OK] {arch:22s} {shape_name:12s} mesh={rec['mesh']:8s} "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"hbm/dev={rec['hbm_bytes_per_device']:.3e} "
              f"wire={counters['wire_bytes']:.3e} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single- and multi-pod meshes")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    todo = []
    if args.all:
        todo = [(a, s) for a, s, _ in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        ok, reason = shape_supported(args.arch, args.shape)
        if not ok:
            print(f"[SKIP] {args.arch} {args.shape}: {reason}")
            return
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if args.both else [args.multi_pod]
    failures = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, out_dir=args.out)
            except Exception:
                failures.append((arch, shape, mp))
                print(f"[FAIL] {arch} {shape} multi_pod={mp}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("dry-run complete")


if __name__ == "__main__":
    main()
