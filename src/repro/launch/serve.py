"""Serving CLI: thin driver over the continuous-batching engine.

The old straight-line decode loop now lives in ``repro.serve.ServeEngine``:
a request queue + slot scheduler + slotted KV cache keep decode running at
full batch width under staggered arrivals, with the model's L1-pruned
weights and the LM head streamed in the paper's bitmap-compressed format
through the ``kernels/ops`` dispatch (see DESIGN.md / EXPERIMENTS.md §Perf).

Run (CPU example, staggered Poisson arrivals):
  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --sparsity 0.5
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.serve import FaultPlan, ServeEngine, ServeOverloaded, \
    poisson_trace


def serve(arch: str, smoke: bool = True, batch: int = 4, steps: int = 32,
          max_len: int = 128, sparsity: float = 0.0, seed: int = 0,
          model_parallel: int = 1) -> dict:
    """Lock-step compatibility wrapper: ``batch`` simultaneous requests,
    each decoding ``steps`` tokens — the old serve() contract, now routed
    through the engine (returns the (batch, steps) greedy token matrix).

    ``head_sparsity=0.0`` keeps the old contract's *numerics*: the whole
    stack (and the head) streams through the bitmap path but packing is
    lossless, so for token-frontend archs the greedy tokens match the
    pre-engine straight-line loop (which served dense) exactly.  Frames-
    frontend archs (musicgen) derive their per-step embeds from a jax
    PRNG key folded with the step counter — same distribution, different
    sequence than the old host-RNG loop.
    """
    eng = ServeEngine.from_arch(arch, smoke=smoke, num_slots=batch,
                                max_len=max_len, sparsity=sparsity,
                                seed=seed, model_parallel=model_parallel,
                                head_sparsity=0.0)
    if sparsity > 0:
        print(f"serving at {eng.weight_sparsity:.2%} weight sparsity "
              f"(head compression {eng.head_compression:.2f}x)")
    rng = np.random.default_rng(seed)
    first = rng.integers(0, eng.cfg.vocab_size, (batch, 1))
    with eng.mesh:
        reqs = [eng.submit([int(first[b, 0])], max_new_tokens=steps)
                for b in range(batch)]
        rep = eng.run()
    tokens = np.stack([np.asarray(r.tokens, np.int32) for r in reqs])
    print(f"decoded {steps} steps x batch {batch} in {rep['wall_s']:.2f}s "
          f"({rep['tok_per_s']:.1f} tok/s)")
    return {"tokens": tokens, "tok_per_s": rep["tok_per_s"],
            "report": rep}


def serve_trace(arch: str, smoke: bool = True, slots: int = 4,
                requests: int = 8, rate: float = 0.5, max_len: int = 128,
                max_new: tuple = (8, 24), sparsity: float = 0.0,
                head_sparsity: float | None = None, seed: int = 0,
                model_parallel: int = 1, stream_weights: bool = True,
                temperature: float = 0.0, top_k: int = 0,
                paged: bool = False, page_len: int = 16,
                page_pool_tokens: int | None = None,
                prefill_chunk: int = 0, prefix_reuse: bool = False,
                preempt: bool = False,
                deadline_ms: float | None = None,
                max_queue: int | None = None,
                ttft_budget_ms: float | None = None,
                max_preempts: int = 8, audit: bool = False,
                faults: "FaultPlan | None" = None,
                trace_out: str | None = None,
                events_out: str | None = None,
                metrics_out: str | None = None,
                traffic_out: str | None = None,
                verbose: bool = True) -> dict:
    """Continuous-batching mode: seeded Poisson arrivals into the engine.

    ``head_sparsity`` defaults to ``sparsity`` (the serving regime: the
    LM head is per-tensor pruned before bitmap packing); pass 0.0 to
    stream the exact dense head.  ``stream_weights=False`` serves a
    fully dense-dispatch baseline (no stack streaming, dense head).
    ``temperature`` > 0 samples every request at that temperature
    (top-``top_k`` truncated) with per-request seeds; default greedy.
    ``paged`` pages the KV cache into ``page_len``-token pages
    (``page_pool_tokens`` bounds each pool; out-of-pages admissions
    queue) — tokens are identical to the contiguous cache.
    ``prefill_chunk`` > 0 ingests prompts through batched
    ``prefill_chunk``-token prefill calls instead of teacher-forcing
    them one token per decode step (0 = the legacy walk); tokens are
    identical either way.
    ``prefix_reuse`` (with ``paged``) maps requests' matching prompt
    prefixes copy-on-write onto already-resident KV pages and skips
    their prefill; ``preempt`` commits live pages only and reclaims by
    preempting + recomputing the youngest slot when the pool runs dry.
    Tokens are identical with both on or off.
    ``deadline_ms`` expires requests that miss their latency budget;
    ``max_queue`` / ``ttft_budget_ms`` shed arrivals under overload
    (``ServeOverloaded`` — counted, not fatal); ``audit`` runs the
    step-level invariant auditor + packed-tensor integrity scan;
    ``faults`` injects a seeded ``repro.serve.FaultPlan`` (chaos
    testing — see DESIGN_SERVING.md §Failure semantics).
    ``trace_out`` / ``events_out`` / ``metrics_out`` write the Chrome
    trace-event JSON (perfetto-viewable step-phase + per-request spans),
    the structured JSONL event log, and the metrics snapshot (`.prom`
    for Prometheus text, else JSON) — see DESIGN_SERVING.md
    §Observability.  ``traffic_out`` writes the memory-traffic
    attribution artifact (per-role ledger, per-phase byte counters,
    compiled-HLO cross-check, energy/roofline projection — the input to
    ``scripts/traffic_report.py`` and the CI budget gate).  All four
    default off; off is bit-identical.
    """
    eng = ServeEngine.from_arch(arch, smoke=smoke, num_slots=slots,
                                max_len=max_len, sparsity=sparsity,
                                head_sparsity=head_sparsity,
                                seed=seed, model_parallel=model_parallel,
                                stream_weights=stream_weights,
                                bitmap_head=stream_weights, top_k=top_k,
                                paged=paged, page_len=page_len,
                                page_pool_tokens=page_pool_tokens,
                                prefill_chunk=prefill_chunk,
                                prefix_reuse=prefix_reuse,
                                preempt=preempt,
                                deadline_ms=deadline_ms,
                                max_queue=max_queue,
                                ttft_budget_ms=ttft_budget_ms,
                                max_preempts=max_preempts,
                                audit=audit, faults=faults,
                                trace_out=trace_out,
                                events_out=events_out,
                                metrics_out=metrics_out,
                                traffic_out=traffic_out)
    prompt_len = (1, min(4, max_len))
    hi = max(1, min(max_new[1], max_len - prompt_len[1] + 1))
    lo = max(1, min(max_new[0], hi))
    trace = poisson_trace(requests, rate=rate, seed=seed,
                          vocab_size=eng.cfg.vocab_size,
                          prompt_len=prompt_len, max_new=(lo, hi))
    shed_at_submit = 0
    with eng.mesh:
        for spec in trace:
            try:
                eng.submit(**spec, temperature=temperature)
            except ServeOverloaded:
                # admission control said no — the typed rejection is the
                # feature, not a failure; count it and keep the trace going
                shed_at_submit += 1
        rep = eng.run()
    for path in eng.close():
        if verbose:
            print(f"telemetry written: {path}")
    if verbose:
        ws = rep["weight_stream"]
        print(f"weight stream: {ws['packed_tensors']} tensors packed, "
              f"{ws['fallback_tensors']} dense fallbacks | modeled "
              f"per-step weight HBM {ws['sparse_bytes_per_step']/1e6:.2f}MB"
              f" vs dense {ws['dense_bytes_per_step']/1e6:.2f}MB "
              f"({ws['reduction']:.2f}x)")
        if rep["head_fallback"]:
            print(f"  head fallback: {rep['head_fallback']}")
        tr = rep["traffic"]
        td, tp = tr["phases"]["decode"], tr["phases"]["prefill"]
        en = tr["energy"]
        print(f"traffic: decode {td['weight_bytes']/1e6:.2f}MB weights + "
              f"{(td['kv_read_bytes'] + td['kv_write_bytes'])/1e6:.2f}MB "
              f"KV over {td['steps']} steps"
              + (f", prefill {tp['weight_bytes']/1e6:.2f}MB weights over "
                 f"{tp['calls']} calls" if tp["calls"] else "")
              + f" | {en['pj_per_token']/1e6:.2f}uJ/token "
                f"({en['tops_per_watt']:.2f} TOPS/W vs dense "
                f"{en['tops_per_watt_dense']:.2f})")
        if tr["crosscheck"] is not None:
            for ph in ("decode", "prefill"):
                if ph in tr["crosscheck"]:
                    cx = tr["crosscheck"][ph]
                    lo, hi = cx["tolerance"]
                    print(f"  {ph} modeled-vs-compiled: "
                          f"{cx['modeled']['total_bytes']/1e6:.2f}MB vs "
                          f"{cx['compiled_bytes']/1e6:.2f}MB "
                          f"(ratio {cx['ratio']:.2f}, band "
                          f"[{lo:g}, {hi:g}] "
                          f"{'ok' if cx['within_band'] else 'VIOLATED'})")
        if sparsity > 0:
            print(f"serving at {eng.weight_sparsity:.2%} weight sparsity "
                  f"(head compression {eng.head_compression:.2f}x)")
        pf = rep["prefill"]
        if pf["enabled"]:
            tt = rep["ttft"]
            print(f"prefill: {pf['calls']} chunk calls ({pf['chunk']} "
                  f"tokens) over {pf['prefill_steps']} prefill + "
                  f"{pf['decode_steps']} decode steps | TTFT split p50 "
                  f"queue {tt['queue_s']['p50'] * 1e3:.1f}ms / prefill "
                  f"{tt['prefill_s']['p50'] * 1e3:.1f}ms / first decode "
                  f"{tt['first_decode_s']['p50'] * 1e3:.1f}ms")
        elif pf["fallback"]:
            print(f"  prefill fallback: {pf['fallback']}")
        lat, ftl = rep["latency_s"], rep["first_token_s"]
        pg = rep["paging"]
        if pg["paged"]:
            print(f"paged KV: {pg['pages_peak']} peak / "
                  f"{pg['pages_total']} pool pages ({pg['page_len']} "
                  f"tokens each) | reserved KV "
                  f"{pg['reserved_kv_bytes']/1e3:.1f}kB vs contiguous "
                  f"{pg['contiguous_kv_bytes']/1e3:.1f}kB "
                  f"({pg['reserved_reduction']:.2f}x)")
        elif pg["fallback"]:
            print(f"  paging fallback: {pg['fallback']}")
        pr = rep["prefix_reuse"]
        if pr["enabled"]:
            split = ""
            if pr["hit_requests"] and pr["miss_requests"]:
                split = (f" | TTFT p50 hit "
                         f"{pr['ttft_hit_s']['p50'] * 1e3:.1f}ms vs miss "
                         f"{pr['ttft_miss_s']['p50'] * 1e3:.1f}ms")
            print(f"prefix reuse: {pr['hits']} hits / {pr['misses']} "
                  f"misses ({pr['hit_tokens']} tokens adopted, "
                  f"{pr['forks']} COW forks, {pr['evictions']} "
                  f"evictions){split}")
        elif pr["fallback"]:
            print(f"  prefix-reuse fallback: {pr['fallback']}")
        pe = pr["preempt"]
        if pe["enabled"]:
            print(f"preemption: {pe['count']} preempts, "
                  f"{pe['recomputed_tokens']} tokens recomputed")
        elif pe["fallback"]:
            print(f"  preempt fallback: {pe['fallback']}")
        lc = rep["lifecycle"]
        shed = lc["shed"] + shed_at_submit
        if lc["cancelled"] or lc["expired"] or shed:
            print(f"lifecycle: {lc['cancelled']} cancelled / "
                  f"{lc['expired']} expired / {shed} shed "
                  f"({lc['wasted_tokens']} tokens wasted)")
        if lc["quarantined"]:
            print(f"  quarantined tensors: "
                  f"{', '.join(sorted(lc['quarantined']))}")
        if "faults" in lc:
            fs = lc["faults"]
            print(f"fault injection: {fs['fired']}/{fs['planned']} "
                  f"faults fired (seed {fs['seed']})")
        if "audit" in lc:
            au = lc["audit"]
            print(f"audit: {au['steps_checked']} steps checked, "
                  f"{au['integrity_scans']} integrity scans over "
                  f"{au['checksummed_tensors']} tensors, 0 violations")
        print(f"{rep['requests']} requests / {rep['generated_tokens']} "
              f"tokens in {rep['wall_s']:.2f}s over {slots} slots "
              f"(occupancy {rep['slot_occupancy']:.0%})")
        print(f"  throughput {rep['tok_per_s']:.1f} tok/s | latency "
              f"p50 {lat['p50'] * 1e3:.1f}ms p99 {lat['p99'] * 1e3:.1f}ms "
              f"| first-token p50 {ftl['p50'] * 1e3:.1f}ms "
              f"p99 {ftl['p99'] * 1e3:.1f}ms")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per decode step (Poisson)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--head-sparsity", type=float, default=None,
                    help="LM-head prune level before bitmap packing "
                         "(default: --sparsity; 0 = exact dense head)")
    ap.add_argument("--dense-stack", action="store_true",
                    help="disable all bitmap weight streaming (stack and "
                         "head): a fully dense-dispatch baseline")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="default top-k truncation for sampled requests "
                         "(0 = off; requests may override per-submit)")
    ap.add_argument("--paged", action="store_true",
                    help="page the KV cache (fixed-size pages + per-slot "
                         "page tables; reserved bytes scale with live "
                         "tokens)")
    ap.add_argument("--page-len", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--page-pool-tokens", type=int, default=None,
                    help="bound each page pool to this many tokens "
                         "(default: worst case; smaller pools queue "
                         "admissions when pages run out)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="ingest prompts in batched chunks of this many "
                         "tokens per prefill call (0 = legacy teacher-"
                         "forcing through decode steps)")
    ap.add_argument("--prefix-reuse", action="store_true",
                    help="share matching prompt prefixes copy-on-write "
                         "across requests (with --paged): cache hits "
                         "skip prefill entirely")
    ap.add_argument("--preempt", action="store_true",
                    help="commit live pages only and reclaim by "
                         "preempting + recomputing the youngest slot "
                         "when the pool runs dry (with --paged)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget from arrival-due to "
                         "completion; misses end EXPIRED (typed "
                         "DeadlineExceeded in request.result())")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="shed arrivals once this many requests are "
                         "queued (typed ServeOverloaded; counted in "
                         "report()['lifecycle'])")
    ap.add_argument("--ttft-budget-ms", type=float, default=None,
                    help="shed arrivals when estimated TTFT exceeds this "
                         "budget (queue work / measured step rate)")
    ap.add_argument("--max-preempts", type=int, default=8,
                    help="preemption bound: a request preempted this many "
                         "times re-admits pinned (worst-case page "
                         "commitment, never victimized again)")
    ap.add_argument("--audit", action="store_true",
                    help="run the step-level invariant auditor + packed-"
                         "tensor integrity scan every step (corruption "
                         "quarantines to dense + deterministic replay)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a seeded FaultPlan.chaos() fault schedule "
                         "(page squeezes, forced preempts, eviction "
                         "storms, NaN logits, bitflips); implies --audit")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (step-phase + "
                         "per-request spans; open in ui.perfetto.dev or "
                         "chrome://tracing)")
    ap.add_argument("--events-out", default=None,
                    help="write the structured JSONL event log "
                         "(lifecycle transitions, fallbacks, faults, "
                         "audit violations)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics snapshot at exit: Prometheus "
                         "text if the path ends in .prom, else JSON")
    ap.add_argument("--traffic-out", default=None,
                    help="write the memory-traffic attribution artifact "
                         "at exit (per-role HBM ledger, per-phase byte "
                         "counters, compiled-HLO cross-check, energy + "
                         "roofline projection); feed to "
                         "scripts/traffic_report.py")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    faults = (FaultPlan.chaos(seed=args.chaos_seed)
              if args.chaos_seed is not None else None)
    serve_trace(args.arch, smoke=args.smoke, slots=args.slots,
                requests=args.requests, rate=args.rate,
                max_len=args.max_len, sparsity=args.sparsity,
                head_sparsity=args.head_sparsity,
                stream_weights=not args.dense_stack,
                temperature=args.temperature, top_k=args.top_k,
                paged=args.paged, page_len=args.page_len,
                page_pool_tokens=args.page_pool_tokens,
                prefill_chunk=args.prefill_chunk,
                prefix_reuse=args.prefix_reuse, preempt=args.preempt,
                deadline_ms=args.deadline_ms, max_queue=args.max_queue,
                ttft_budget_ms=args.ttft_budget_ms,
                max_preempts=args.max_preempts,
                audit=args.audit or faults is not None, faults=faults,
                trace_out=args.trace_out, events_out=args.events_out,
                metrics_out=args.metrics_out,
                traffic_out=args.traffic_out,
                seed=args.seed, model_parallel=args.model_parallel)


if __name__ == "__main__":
    main()
