"""Batched serving driver: prefill-free batch decode with sparse weights.

Demonstrates the paper's technique at serving time: model weights are
global-L1 pruned and (optionally) converted to the bitmap format whose HBM
traffic the Pallas ``bitmap_spmm`` kernel cuts by ~the density ratio —
decode is memory-bound, so this directly attacks the dominant roofline term
(EXPERIMENTS.md §Perf).

Run (CPU example):
  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_elastic_mesh
from repro.launch.steps import build_serve_step
from repro.models.model import init_cache, init_params
from repro.sparse.pruning import global_l1_prune, sparsity_of


def serve(arch: str, smoke: bool = True, batch: int = 4, steps: int = 32,
          max_len: int = 128, sparsity: float = 0.0, seed: int = 0,
          model_parallel: int = 1) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_elastic_mesh(model_parallel)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    if sparsity > 0:
        params = global_l1_prune(params, sparsity)
        print(f"serving at {sparsity_of(params):.2%} weight sparsity")

    pspecs = shd.named(mesh, shd.param_specs(cfg, mesh))
    params = jax.device_put(params, pspecs)
    cache = init_cache(cfg, batch, max_len)
    step_fn = build_serve_step(cfg)
    rng = np.random.default_rng(seed)

    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(1,))
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 1)),
                          jnp.int32)
        toks_out = []
        t0 = time.time()
        for pos in range(steps):
            if cfg.frontend == "frames":
                emb = jnp.asarray(rng.standard_normal(
                    (batch, 1, cfg.d_model)), jnp.float32)
                nxt, logits, cache = jit_step(params, cache, None,
                                              jnp.int32(pos), embeds=emb)
            else:
                nxt, logits, cache = jit_step(params, cache, tok,
                                              jnp.int32(pos))
            tok = nxt[:, None]
            toks_out.append(np.asarray(nxt))
        dt = time.time() - t0
    tokens = np.stack(toks_out, 1)
    tps = batch * steps / dt
    print(f"decoded {steps} steps x batch {batch} in {dt:.2f}s "
          f"({tps:.1f} tok/s)")
    return {"tokens": tokens, "tok_per_s": tps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, batch=args.batch, steps=args.steps,
          max_len=args.max_len, sparsity=args.sparsity,
          model_parallel=args.model_parallel)


if __name__ == "__main__":
    main()
