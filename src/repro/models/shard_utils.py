"""Activation-sharding hints that degrade to no-ops off-mesh.

``hint(x, *axes)`` applies ``with_sharding_constraint`` when tracing inside
a mesh context, silently dropping axes the mesh doesn't have or that don't
divide the dim — so model code can carry production sharding annotations
while remaining runnable on a single CPU device (smoke tests, examples).

Axis conventions (launch/sharding.py): "batch" expands to ("pod","data");
"model" is tensor parallel; None replicates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        env = jax.interpreters.pxla.thread_resources.env
        mesh = env.physical_mesh
        if mesh.empty:
            return None
        return mesh
    except Exception:
        return None


def _manual_axes(mesh) -> bool:
    """True when tracing inside ``shard_map`` over this mesh — its axes
    are *manual* there, so a with_sharding_constraint naming them is
    both an error and pointless (the per-device layout is explicit)."""
    try:
        from jax._src import core
        bound = core.get_axis_env().axis_sizes
        return any(a in bound for a in mesh.axis_names)
    except Exception:
        return False


def hint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain dim i of x to axis names axes[i] ("batch"/"model"/None)."""
    mesh = _current_mesh()
    if mesh is None or _manual_axes(mesh):
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax == "batch":
            names: Tuple[str, ...] = tuple(
                a for a in ("pod", "data") if a in mesh.axis_names)
            size = 1
            for a in names:
                size *= mesh.shape[a]
            spec.append(names if names and dim % size == 0 and dim > 1
                        else None)
        elif ax is not None and ax in mesh.axis_names:
            spec.append(ax if dim % mesh.shape[ax] == 0 else None)
        else:
            spec.append(None)
    spec += [None] * (len(x.shape) - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*spec))
