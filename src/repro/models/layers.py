"""Neural building blocks (pure functions over param dicts).

Everything is written against the shapes in ``model.param_shapes`` and kept
jit/pjit-friendly: no data-dependent shapes, scan-based attention for long
sequences, sort-based MoE dispatch with static capacity.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ---------------------------------------------------------------- norms ----


def norm(x: jax.Array, scale: Optional[jax.Array], kind: str) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        if scale is not None:
            y = y * (1.0 + scale.astype(jnp.float32))
    elif kind == "ln_nonparam":          # olmo: non-parametric LayerNorm
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    elif kind == "ln":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if scale is not None:
            y = y * (1.0 + scale.astype(jnp.float32))
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


def group_norm_heads(x: jax.Array, scale: jax.Array, heads: int) -> jax.Array:
    """Per-head group norm (RWKV output norm). x: (..., H*Dh)."""
    shp = x.shape
    xf = x.reshape(*shp[:-1], heads, shp[-1] // heads).astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)

# ----------------------------------------------------------------- rope ----


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)

# ------------------------------------------------------------ attention ----


def _online_block(carry, kc, vc, q, q_pos, k_pos, window, scale):
    """One online-softmax step over a KV chunk. q:(B,H,Sq,D) kc:(B,H,C,D)."""
    m_prev, l_prev, acc = carry
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kc,
                   preferred_element_type=jnp.float32) * scale
    mask = q_pos[:, None, :, None] >= k_pos[:, None, None, :]
    if window is not None:
        mask &= (q_pos[:, None, :, None] - k_pos[:, None, None, :]) < window
    s = jnp.where(mask, s, -1e30)
    m_cur = jnp.max(s, -1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum(-1, keepdims=True)
    acc_new = alpha * acc + jnp.einsum(
        "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
    return (m_new, l_new, acc_new)


def scan_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   positions: jax.Array, *, window: Optional[int] = None,
                   q_chunk: int = 2048, kv_chunk: int = 512) -> jax.Array:
    """Causal flash-style attention in pure XLA (O(S) memory).

    q: (B, S, Hq, D); k/v: (B, S, Hkv, D); positions: (B, S).
    Python loop over query chunks; each chunk scans only the causally
    reachable KV prefix (FLOP-optimal), giving O(n_q) scan bodies in HLO.
    """
    from repro.models.perf_flags import baseline_mode
    if baseline_mode():  # §Perf H4 "before": materialise repeated KV
        g0 = q.shape[2] // k.shape[2]
        k = jnp.repeat(k, g0, axis=2)
        v = jnp.repeat(v, g0, axis=2)
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)

    # GQA without materialising repeated KV (§Perf H4): fold the group dim
    # into the query-sequence dim (s-major) so each KV head serves its G
    # query heads through the same (B, Hkv, ·) tiles — an 8× KV traffic cut
    # at kv=8 / 64 heads.
    qh = (q.reshape(b, s, hkv, g, d).transpose(0, 2, 1, 3, 4)
          .reshape(b, hkv, s * g, d).astype(jnp.float32))
    kh = jnp.swapaxes(k, 1, 2).astype(jnp.float32)      # (B, Hkv, S, D)
    vh = jnp.swapaxes(v, 1, 2).astype(jnp.float32)

    outs = []
    n_q = -(-s // q_chunk)
    for iq in range(n_q):
        q0 = iq * q_chunk
        q1 = min(q0 + q_chunk, s)
        qc = qh[:, :, q0 * g:q1 * g]
        qp = jnp.repeat(positions[:, q0:q1], g, axis=1)  # (B, (q1-q0)*g)
        kv_hi = q1  # causal reach
        if window is not None:
            kv_lo = max(0, (q0 - window + 1) // kv_chunk * kv_chunk)
        else:
            kv_lo = 0
        n_kv = -(-(kv_hi - kv_lo) // kv_chunk)
        kv_len = n_kv * kv_chunk
        kc = jax.lax.dynamic_slice_in_dim(
            kh, kv_lo, min(kv_len, s - kv_lo), axis=2)
        vc = jax.lax.dynamic_slice_in_dim(
            vh, kv_lo, min(kv_len, s - kv_lo), axis=2)
        if kc.shape[2] < kv_len:  # pad tail chunk
            pad = kv_len - kc.shape[2]
            kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kcs = kc.reshape(b, hkv, n_kv, kv_chunk, d).transpose(2, 0, 1, 3, 4)
        vcs = vc.reshape(b, hkv, n_kv, kv_chunk, d).transpose(2, 0, 1, 3, 4)
        kp = (kv_lo + jnp.arange(kv_len)).reshape(n_kv, kv_chunk)
        kp = jnp.broadcast_to(kp[:, None, :], (n_kv, b, kv_chunk))
        qn = (q1 - q0) * g
        init = (jnp.full((b, hkv, qn, 1), -1e30, jnp.float32),
                jnp.zeros((b, hkv, qn, 1), jnp.float32),
                jnp.zeros((b, hkv, qn, d), jnp.float32))

        def step(carry, xs):
            kcb, vcb, kpb = xs
            return _online_block(carry, kcb, vcb, qc, qp, kpb, window,
                                 scale), None

        (m, l, acc), _ = jax.lax.scan(step, init, (kcs, vcs, kp))
        outs.append(acc / jnp.maximum(l, 1e-30))
    out = jnp.concatenate(outs, axis=2)                  # (B, Hkv, S*g, D)
    out = (out.reshape(b, hkv, s, g, d).transpose(0, 2, 1, 3, 4)
           .reshape(b, s, hq, d))
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None,
                     ring: bool = False) -> jax.Array:
    """Single-token attention against a cache.

    q: (B, 1, Hq, D); caches: (B, C, Hkv, D); pos: scalar current position,
    or a (B,) vector of per-sequence positions (continuous batching: each
    batch slot decodes at its own offset).
    ``ring`` marks a sliding-window ring buffer of size C == window.
    """
    from repro.models.perf_flags import baseline_mode
    if baseline_mode():  # §Perf H4 "before"
        g0 = q.shape[2] // k_cache.shape[2]
        k_cache = jnp.repeat(k_cache, g0, axis=2)
        v_cache = jnp.repeat(v_cache, g0, axis=2)
    b, c, hkv, d = k_cache.shape
    hq = q.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    # grouped-query einsum — never materialise repeated KV (§Perf H4);
    # KV stays in cache dtype with f32 MXU accumulation (§Perf iter 3):
    # upcasting the KV shard to f32 per layer doubles decode HBM traffic.
    qh = q[:, 0].reshape(b, hkv, g, d).astype(k_cache.dtype)
    s = jnp.einsum("bkgd,bckd->bkgc", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = s.reshape(b, hq, c)
    pos = jnp.asarray(pos)
    pc = (jnp.broadcast_to(pos, (b,)) if pos.ndim == 0 else pos)[:, None]
    slots = jnp.arange(c)[None, :]
    if ring:
        # slot i holds the latest position p <= pos with p % C == i;
        # cold slots imply p < 0 and must be masked out
        base = pc - (pc % c)
        slot_pos = jnp.where(slots <= (pc % c), base + slots,
                             base - c + slots)
    else:
        slot_pos = jnp.broadcast_to(slots, (b, c))
    valid = (slot_pos <= pc) & (slot_pos >= 0)
    if window is not None:
        valid &= (pc - slot_pos) < window
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).reshape(b, hkv, g, c)
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)

def paged_kv_update(k_pool: jax.Array, v_pool: jax.Array, k: jax.Array,
                    v: jax.Array, page_table: jax.Array,
                    write_slot: jax.Array,
                    valid: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Write one decode step's K/V lines through a page table.

    k_pool/v_pool: (NP, L, Hkv, D) page pools (NP physical pages of L
    tokens; page 0 is the reserved trash page).  k/v: (B, 1, Hkv, D).
    page_table: (B, S) int32 physical page ids, 0 = unmapped.
    write_slot: (B,) logical token slot in [0, S*L).

    Rows whose logical page is unmapped (idle batch slots decoding at
    position 0) resolve to page 0 and scribble into the trash line —
    live pages are only ever written by the slot that owns them, so
    distinct rows never collide outside the trash page.

    ``valid`` ((B,) bool) additionally routes masked-off rows to the
    trash page — chunked prefill runs a fixed-width batch where slots
    past their chunk length must not touch live pages.
    """
    page_len = k_pool.shape[1]
    pi = write_slot // page_len
    off = write_slot % page_len
    phys = jnp.take_along_axis(page_table, pi[:, None], axis=1)[:, 0]
    if valid is not None:
        phys = jnp.where(valid, phys, 0)
    k_pool = k_pool.at[phys, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[phys, off].set(v[:, 0].astype(v_pool.dtype))
    return k_pool, v_pool


def slot_kv_update(k_cache: jax.Array, v_cache: jax.Array, k: jax.Array,
                   v: jax.Array, write_slot: jax.Array,
                   valid: Optional[jax.Array] = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Write one K/V line per batch row into the contiguous slotted cache.

    k_cache/v_cache: (B, C, Hkv, D); k/v: (B, 1, Hkv, D); write_slot: (B,)
    cache line per row.  ``valid`` ((B,) bool) drops masked-off rows from
    the scatter entirely (the contiguous layout has no trash line, so
    chunked prefill's padding lanes redirect out of bounds and are
    dropped) — decode's unconditional write passes no mask and keeps its
    exact scatter.
    """
    b = k_cache.shape[0]
    bidx = jnp.arange(b)
    if valid is None:
        k_cache = k_cache.at[bidx, write_slot].set(
            k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, write_slot].set(
            v[:, 0].astype(v_cache.dtype))
        return k_cache, v_cache
    slot = jnp.where(valid, write_slot, k_cache.shape[1])   # OOB when masked
    k_cache = k_cache.at[bidx, slot].set(
        k[:, 0].astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[bidx, slot].set(
        v[:, 0].astype(v_cache.dtype), mode="drop")
    return k_cache, v_cache


def paged_gather(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather each slot's pages into a contiguous (B, S*L, H, D) view.

    Unmapped entries (0) gather the trash page — garbage lines that the
    attention validity mask (slot_pos <= pos, window) always excludes, so
    pages never need zeroing when they move between requests.
    """
    b, s = page_table.shape
    lines = pool[page_table.reshape(-1)]            # (B*S, L, H, D)
    return lines.reshape(b, s * pool.shape[1], *pool.shape[2:])


# ----------------------------------------------------------------- MoE -----


def expert_matmul_or_bitmap(h: jax.Array, w: jax.Array, bw, impl
                            ) -> jax.Array:
    """Per-expert GEMM ``h[..., e, :, :] @ w[e]`` for expert stacks.

    h: (..., E, C, K); w: (E, K, N).  A group-stacked ``BitmapWeight``
    (``bw`` — see ``sparse.format.pack_bitmap_experts``) streams each
    expert's compressed tiles through ``kernels/ops.bitmap_spmm_grouped``
    instead; ``bw is None`` keeps the dense einsum both MoE dispatch
    variants always ran."""
    if bw is None:
        return jnp.einsum("...eck,ekn->...ecn", h, w.astype(h.dtype))
    from repro.kernels import ops  # lazy: layers must not import kernels
    lead = h.shape[:-3]
    e, c, k = h.shape[-3:]
    hx = jnp.moveaxis(h.reshape((-1, e, c, k)), 1, 0).reshape(e, -1, k)
    out = ops.bitmap_spmm_grouped(hx, bw, impl=impl)
    n = out.shape[-1]
    return jnp.moveaxis(out.reshape(e, -1, c, n), 0, 1).reshape(
        lead + (e, c, n))


def _moe_ffn_global(params: dict, x: jax.Array, cfg: ModelConfig,
                    packed: Optional[dict] = None,
                    impl: Optional[str] = None) -> jax.Array:
    """§Perf H3 "before": global flat-token dispatch (argsort across the
    whole batch) — forces GSPMD to all-gather the token buffer."""
    pk = packed or {}
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    cap = int(t * k * cfg.capacity_factor / e) + 1
    xt = x.reshape(t, d)
    logits = matmul_or_bitmap(xt, params["router"], pk.get("router"), impl)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, expert_idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    flat_expert = expert_idx.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    same = jnp.cumsum(jax.nn.one_hot(sorted_expert, e, dtype=jnp.int32), 0)
    rank = same[jnp.arange(t * k), sorted_expert] - 1
    keep = rank < cap
    slot = sorted_expert * cap + jnp.where(keep, rank, 0)
    src_token = order // k
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[src_token], 0))
    buf = buf.reshape(e, cap, d)
    h = activation(expert_matmul_or_bitmap(buf, params["w_gate"],
                                           pk.get("w_gate"), impl), cfg.act)
    h = h * expert_matmul_or_bitmap(buf, params["w_up"], pk.get("w_up"),
                                    impl)
    y = expert_matmul_or_bitmap(h, params["w_down"], pk.get("w_down"),
                                impl).reshape(e * cap, d)
    gath = jnp.where(keep[:, None], y[slot], 0)
    gval = gate.reshape(-1)[order]
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[src_token].add(gath.astype(jnp.float32) * gval[:, None])
    return out.reshape(b, s, d).astype(x.dtype)


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig,
            packed: Optional[dict] = None,
            impl: Optional[str] = None) -> jax.Array:
    """Sort-based top-k MoE with static capacity. x: (B, S, D) -> (B, S, D).

    Dispatch is *per batch row* (§Perf H3): the sort, ranking and bucket
    scatter are all vectorised over B with no cross-row dataflow, so with B
    sharded over the data axes GSPMD keeps dispatch entirely local — a
    global flat-token argsort forces all-gathers of the whole token buffer.
    Capacity is per-row: C = ceil(S·k·cf / E); overflow tokens are dropped
    (standard capacity dispatch).  Expert weights shard on the FFN dim
    ("model"), so the expert einsums are local too.

    ``packed`` maps ``router`` to a period-stacked ``BitmapWeight`` and
    ``w_gate``/``w_up``/``w_down`` to expert-stacked ones (serve-time
    compressed streaming — see repro.serve.packed / DESIGN_PACKED.md);
    present entries dispatch per-expert bitmap SpMM through kernels/ops.
    """
    from repro.models import shard_utils
    from repro.models.perf_flags import baseline_mode
    if baseline_mode():
        return _moe_ffn_global(params, x, cfg, packed=packed, impl=impl)
    pk = packed or {}
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = int(s * k * cfg.capacity_factor / e) + 1

    logits = matmul_or_bitmap(x, params["router"], pk.get("router"), impl)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, expert_idx = jax.lax.top_k(probs, k)           # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)    # (B, S*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # rank within expert group = position − first occurrence (sorted rows);
    # §Perf iter 4: the one-hot/cumsum rank cost (B, S·k, E) int traffic
    # (~900 GB/step for moonshot); searchsorted is O(S·k·log)
    first = jax.vmap(lambda row: jnp.searchsorted(row, row, side="left")
                     )(sorted_e)
    rank = jnp.arange(s * k)[None, :] - first
    keep = rank < cap
    slot = sorted_e * cap + jnp.where(keep, rank, 0)     # (B, S*k)
    src = order // k                                      # token id per row

    rows = jnp.arange(b)[:, None]
    gathered = jnp.take_along_axis(x, src[..., None], axis=1)  # (B,S*k,D)
    buf = jnp.zeros((b, e * cap, d), x.dtype)
    buf = buf.at[rows, slot].add(
        jnp.where(keep[..., None], gathered, 0))
    buf = shard_utils.hint(buf.reshape(b, e, cap, d), "batch")

    h = activation(expert_matmul_or_bitmap(buf, params["w_gate"],
                                           pk.get("w_gate"), impl), cfg.act)
    h = h * expert_matmul_or_bitmap(buf, params["w_up"], pk.get("w_up"),
                                    impl)
    # §Perf iter 4: gather h across the F shards so the w_down contraction
    # and the whole combine run locally on D shards (no capacity-buffer AR)
    h = shard_utils.hint(h, "batch", None, None, None)
    y = expert_matmul_or_bitmap(h, params["w_down"], pk.get("w_down"),
                                impl).reshape(b, e * cap, d)
    y = shard_utils.hint(y, "batch", None, "model")

    out_tok = jnp.take_along_axis(y, slot[..., None], axis=1)  # (B,S*k,D)
    gval = jnp.take_along_axis(gate.reshape(b, s * k), order, axis=-1)
    contrib = jnp.where(keep[..., None], out_tok, 0).astype(jnp.float32)
    out = jnp.zeros((b, s, d), jnp.float32)
    out = out.at[rows, src].add(contrib * gval[..., None])
    return out.astype(x.dtype)


def matmul_or_bitmap(h: jax.Array, w: jax.Array, bw, impl) -> jax.Array:
    """One projection: dense ``h @ w`` unless a packed ``BitmapWeight`` is
    provided, in which case the matmul streams the compressed form through
    ``kernels/ops.bitmap_spmm`` (xla ref on CPU, Pallas on TPU) — packing
    is lossless, so the two paths are numerically identical."""
    if bw is None:
        return h @ w.astype(h.dtype)
    from repro.kernels import ops  # lazy: layers must not import kernels
    return ops.bitmap_spmm(h, bw, impl=impl)


def mlp(params: dict, x: jax.Array, cfg: ModelConfig,
        packed: Optional[dict] = None, impl: Optional[str] = None
        ) -> jax.Array:
    """Gated/plain MLP; ``packed`` maps weight names to ``BitmapWeight``s
    (serve-time compressed streaming — see repro.serve.packed)."""
    pk = packed or {}
    if "w_gate" in params:
        h = activation(matmul_or_bitmap(x, params["w_gate"],
                                        pk.get("w_gate"), impl), cfg.act)
        h = h * matmul_or_bitmap(x, params["w_up"], pk.get("w_up"), impl)
    else:
        h = activation(matmul_or_bitmap(x, params["w_up"],
                                        pk.get("w_up"), impl), cfg.act)
    return matmul_or_bitmap(h, params["w_down"], pk.get("w_down"), impl)
