"""Unified decoder LM: shapes, init, forward, decode, loss.

One model covers all ten assigned architectures through ``ModelConfig``:
the layer stack is ``lax.scan`` over ``num_periods`` repetitions of the
(possibly heterogeneous) block pattern, with ``jax.checkpoint`` on the
period body — O(1) HLO in depth and one residual per layer of activation
memory.  Parameters are stored stacked over periods: leading dim P on every
block leaf.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import shard_utils
from repro.models import ssm
from repro.models.config import BlockCfg, ModelConfig

RWKV_MIX_RANK = 32
RWKV_DECAY_RANK = 64

# ------------------------------------------------------------- shapes ------


def _block_shapes(cfg: ModelConfig, blk: BlockCfg) -> Dict[str, tuple]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    f = cfg.d_ff
    shp: Dict[str, tuple] = {}
    if blk.mixer == "attn":
        shp["attn"] = {
            "norm": (d,), "wq": (d, h * hd), "wk": (d, kv * hd),
            "wv": (d, kv * hd), "wo": (h * hd, d),
        }
        if cfg.qk_norm:
            shp["attn"]["q_norm"] = (hd,)
            shp["attn"]["k_norm"] = (hd,)
    elif blk.mixer == "mamba":
        di, n, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
        shp["mamba"] = {
            "norm": (d,), "in_proj": (d, 2 * di),
            "conv_w": (di, cfg.mamba_conv), "conv_b": (di,),
            "x_proj": (di, dtr + 2 * n), "dt_proj": (dtr, di),
            "dt_bias": (di,), "A_log": (di, n), "D": (di,),
            "out_proj": (di, d),
        }
    elif blk.mixer == "rwkv":
        hh = cfg.rwkv_heads
        shp["rwkv"] = {
            "norm": (d,), "mix_mu": (5, d),
            "mix_A": (d, 5 * RWKV_MIX_RANK),
            "mix_B": (5, RWKV_MIX_RANK, d),
            "w0": (d,), "decay_A": (d, RWKV_DECAY_RANK),
            "decay_B": (RWKV_DECAY_RANK, d),
            "w_r": (d, d), "w_k": (d, d), "w_v": (d, d), "w_g": (d, d),
            "w_o": (d, d), "u": (hh, cfg.rwkv_head_dim), "gn_scale": (d,),
        }
    else:
        raise ValueError(blk.mixer)

    if blk.ffn == "mlp":
        shp["mlp"] = {"norm": (d,), "w_up": (d, f), "w_down": (f, d)}
        if cfg.act == "silu":
            shp["mlp"]["w_gate"] = (d, f)
    elif blk.ffn == "moe":
        e = cfg.num_experts
        shp["moe"] = {
            "norm": (d,), "router": (d, e), "w_gate": (e, d, f),
            "w_up": (e, d, f), "w_down": (e, f, d),
        }
    elif blk.ffn == "rwkv_cm":
        shp["rwkv_cm"] = {"norm": (d,), "cm_mu": (2, d), "cm_k": (d, f),
                          "cm_v": (f, d), "cm_r": (d, d)}
    elif blk.ffn == "none":
        pass
    else:
        raise ValueError(blk.ffn)
    return shp


def param_shapes(cfg: ModelConfig) -> Dict:
    """Nested dict of shape tuples (block leaves stacked over periods)."""
    p = cfg.num_periods
    blocks = {}
    for i, blk in enumerate(cfg.pattern):
        blocks[f"b{i}"] = jax.tree.map(
            lambda s: (p,) + s, _block_shapes(cfg, blk),
            is_leaf=lambda x: isinstance(x, tuple))
    shapes = {
        "embed": (cfg.vocab_size, cfg.d_model),
        "final_norm": (cfg.d_model,),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab_size)
    return shapes


def param_structs(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStruct pytree for allocation-free lowering (dry-run)."""
    dt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, dt),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(rng, len(flat))
    dt = jnp.dtype(cfg.param_dtype)
    depth_scale = 1.0 / math.sqrt(2 * cfg.num_layers)

    leaves = []
    for (path, shape), key in zip(flat, keys):
        name = jax.tree_util.keystr(path).lower()
        if "a_log" in name:
            n = shape[-1]
            leaf = jnp.broadcast_to(
                jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), shape)
        elif "dt_bias" in name:
            leaf = jnp.full(shape, math.log(math.expm1(0.01)))
        elif "mix_mu" in name or name.endswith("['u']"):
            leaf = jnp.full(shape, 0.5)
        elif "w0" in name:
            leaf = jnp.full(shape, -1.0) + 0.5 * jax.random.normal(key, shape)
        elif "gn_scale" in name or "cm_mu" in name:
            leaf = jnp.full(shape, 1.0 if "gn" in name else 0.5)
        elif "norm" in name:
            leaf = jnp.zeros(shape)
        elif "conv_b" in name or name.endswith("['d']"):
            leaf = (jnp.zeros(shape) if "conv" in name
                    else jnp.ones(shape))
        elif "embed" in name:
            leaf = 0.02 * jax.random.normal(key, shape)
        elif any(k in name for k in ("wo", "out_proj", "w_down", "w_o")):
            leaf = (0.02 * depth_scale) * jax.random.normal(key, shape)
        else:
            leaf = 0.02 * jax.random.normal(key, shape)
        leaves.append(leaf.astype(dt))
    return jax.tree.unflatten(treedef, leaves)

# ------------------------------------------------------------ forward ------


def _apply_attn(p: Dict, x: jax.Array, cfg: ModelConfig, blk: BlockCfg,
                positions: jax.Array) -> jax.Array:
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    dt_ = x.dtype
    xn = L.norm(x, p.get("norm"), cfg.norm)
    q = (xn @ p["wq"].astype(dt_)).reshape(b, s, h, hd)
    k = (xn @ p["wk"].astype(dt_)).reshape(b, s, kv, hd)
    v = (xn @ p["wv"].astype(dt_)).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = L.norm(q, p["q_norm"], "rmsnorm")
        k = L.norm(k, p["k_norm"], "rmsnorm")
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    o = L.scan_attention(q, k, v, positions, window=blk.window)
    return (o.reshape(b, s, h * hd) @ p["wo"].astype(dt_))


def _apply_block(bp: Dict, x: jax.Array, blk: BlockCfg, cfg: ModelConfig,
                 positions: jax.Array) -> jax.Array:
    if blk.mixer == "attn":
        x = x + _apply_attn(bp["attn"], x, cfg, blk, positions)
    elif blk.mixer == "mamba":
        xn = L.norm(x, bp["mamba"].get("norm"), cfg.norm)
        x = x + ssm.mamba_mix(bp["mamba"], xn, cfg)
    elif blk.mixer == "rwkv":
        xn = L.norm(x, bp["rwkv"].get("norm"), cfg.norm)
        x = x + ssm.rwkv_mix(bp["rwkv"], xn, cfg)

    if blk.ffn == "mlp":
        xn = L.norm(x, bp["mlp"].get("norm"), cfg.norm)
        x = x + L.mlp(bp["mlp"], xn, cfg)
    elif blk.ffn == "moe":
        xn = L.norm(x, bp["moe"].get("norm"), cfg.norm)
        x = x + L.moe_ffn(bp["moe"], xn, cfg)
    elif blk.ffn == "rwkv_cm":
        xn = L.norm(x, bp["rwkv_cm"].get("norm"), cfg.norm)
        x = x + ssm.rwkv_channel_mix(bp["rwkv_cm"], xn, cfg)
    return x


def embed_inputs(params: Dict, cfg: ModelConfig,
                 tokens: Optional[jax.Array],
                 embeds: Optional[jax.Array]) -> jax.Array:
    dt_ = jnp.dtype(cfg.compute_dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(dt_))
    if tokens is not None:
        e = jnp.take(params["embed"], tokens, axis=0).astype(dt_)
        if getattr(cfg, "embed_scale", False):
            e = e * math.sqrt(cfg.d_model)
        parts.append(e)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def forward(params: Dict, cfg: ModelConfig,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None) -> jax.Array:
    """Returns final hidden states (B, S, D) after the final norm."""
    x = embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def period_fn(x, period_params):
        for i, blk in enumerate(cfg.pattern):
            x = _apply_block(period_params[f"b{i}"], x, blk, cfg, positions)
        return x, None

    body = period_fn
    if cfg.remat:
        body = jax.checkpoint(period_fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        for per in range(cfg.num_periods):
            sliced = jax.tree.map(lambda a: a[per], params["blocks"])
            x, _ = body(x, sliced)
    return L.norm(x, params.get("final_norm"), cfg.norm)

# --------------------------------------------------------------- loss ------


def lm_head_weight(params: Dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(params: Dict, hidden: jax.Array, targets: jax.Array,
            cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """Chunked vocab-parallel cross entropy.

    hidden: (B, S, D); targets: (B, S) int32, -1 = masked.  The LM head +
    softmax run per sequence chunk so the (B, chunk, V) logits — not the
    (B, S, V) tensor — bound memory; with V sharded over "model" the
    normaliser and the target logit are computed with one-hot reductions
    (Megatron-style vocab-parallel CE).
    """
    b, s, d = hidden.shape
    w = lm_head_weight(params, cfg).astype(hidden.dtype)
    chunk = min(cfg.loss_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    hid = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    tgt = targets.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    from repro.models.perf_flags import baseline_mode

    def chunk_loss_core(h, t):
        # §Perf H1: keep the chunk logits vocab-sharded over "model" —
        # without the hint GSPMD replicates full-vocab logits per device.
        logits = (h @ w).astype(jnp.float32)                # (B, c, V)
        if not baseline_mode():
            logits = shard_utils.hint(logits, "batch", None, "model")
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        vio = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        sel = vio == jnp.maximum(t, 0)[:, :, None]
        tl = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
        mask = (t >= 0).astype(jnp.float32)
        return jnp.sum((lse - tl) * mask), jnp.sum(mask)

    # §Perf H2: remat the chunk — otherwise the scan saves every chunk's
    # (B, c, V) logits as backward residuals (e.g. 13 GB/device for olmo).
    if not baseline_mode():
        chunk_loss_core = jax.checkpoint(chunk_loss_core)

    def chunk_loss(carry, xs):
        h, t = xs
        ls, m = chunk_loss_core(h, t)
        loss_sum, cnt = carry
        return (loss_sum + ls, cnt + m), None

    (loss_sum, cnt), _ = jax.lax.scan(
        chunk_loss, (jnp.float32(0), jnp.float32(0)), (hid, tgt))
    loss = loss_sum / jnp.maximum(cnt, 1)
    return loss, {"loss": loss, "tokens": cnt}


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict]:
    hidden = forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"))
    return lm_loss(params, hidden, batch["targets"], cfg)

# ------------------------------------------------------------- decode ------


def attn_capacity(blk: BlockCfg, max_len: int) -> int:
    """Per-slot KV line count for one attention block: the sliding window
    bounds the live set, so windowed blocks cache a ring of that size."""
    return min(blk.window, max_len) if blk.window else max_len


def paged_layout(cfg: ModelConfig, max_len: int,
                 page_len: int) -> Dict[str, int]:
    """Page-table width per attention block: ``{bname: page_slots}``.

    ``page_slots = ceil(capacity / page_len)`` — the number of page-table
    entries one slot needs to cover its whole capacity (window-bounded for
    sliding-window blocks).  Blocks with no attention mixer carry O(1)
    recurrent state per slot and are not paged.
    """
    assert page_len > 0
    out = {}
    for i, blk in enumerate(cfg.pattern):
        if blk.mixer == "attn":
            out[f"b{i}"] = -(-attn_capacity(blk, max_len) // page_len)
    return out


def paged_addressing(page_slots: int, page_len: int,
                     window: Optional[int]) -> Tuple[int, bool]:
    """(capacity_tokens, ring) for one paged pool — the write addressing
    that the host-side allocator (``PagedKVCache.ensure``) and the
    device-side cache write (``_decode_attn``) must agree on exactly:
    ring pools write at ``pos % capacity``, others clip to the last
    slot.  One definition for both sides, so they cannot drift."""
    cap = page_slots * page_len
    return cap, window is not None and cap >= window


def _cache_shapes(cfg: ModelConfig, blk: BlockCfg, batch: int,
                  max_len: int, page_len: int = 0,
                  pool_pages: Optional[int] = None) -> Dict[str, tuple]:
    p = cfg.num_periods
    hd = cfg.resolved_head_dim
    if blk.mixer == "attn":
        if page_len > 0:
            # paged layout: a pool of fixed-size pages shared across slots
            # (axis 1 = physical page id, axis 2 = line within the page);
            # a per-slot page table maps logical token slots onto pages.
            # Physical page 0 is the reserved trash page (never allocated)
            # that unmapped table entries point at.
            slots = -(-attn_capacity(blk, max_len) // page_len)
            n = (batch * slots + 1) if pool_pages is None else pool_pages
            return {"k": (p, n, page_len, cfg.num_kv_heads, hd),
                    "v": (p, n, page_len, cfg.num_kv_heads, hd)}
        c = attn_capacity(blk, max_len)
        return {"k": (p, batch, c, cfg.num_kv_heads, hd),
                "v": (p, batch, c, cfg.num_kv_heads, hd)}
    if blk.mixer == "mamba":
        return {"h": (p, batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                "conv": (p, batch, cfg.mamba_conv - 1, cfg.mamba_d_inner)}
    if blk.mixer == "rwkv":
        shp = {"s": (p, batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                     cfg.rwkv_head_dim),
               "x_prev": (p, batch, cfg.d_model)}
        return shp
    raise ValueError(blk.mixer)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int,
                  page_len: int = 0,
                  pool_pages: Optional[Dict[str, int]] = None) -> Dict:
    """ShapeDtypeStructs of the decode cache (bf16 KV, f32 SSM states).

    ``page_len > 0`` switches attention leaves to the paged layout:
    ``(P, pool, page_len, Hkv, hd)`` pools indexed through per-slot page
    tables (see ``paged_layout`` / ``repro.serve.paging``), with
    ``pool_pages[bname]`` physical pages per block (default: worst case
    ``batch * page_slots`` + the trash page).  Recurrent (SSM/RWKV) state
    stays slotted — it is O(1) per slot and needs no paging.
    """
    out = {}
    for i, blk in enumerate(cfg.pattern):
        shp = _cache_shapes(cfg, blk, batch, max_len, page_len,
                            (pool_pages or {}).get(f"b{i}"))
        entry = {}
        for k, s in shp.items():
            dt = jnp.float32 if k in ("h", "s") else jnp.dtype(
                cfg.compute_dtype)
            entry[k] = jax.ShapeDtypeStruct(s, dt)
        if blk.ffn == "rwkv_cm":
            entry["cm_x_prev"] = jax.ShapeDtypeStruct(
                (cfg.num_periods, batch, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        out[f"b{i}"] = entry
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               page_len: int = 0,
               pool_pages: Optional[Dict[str, int]] = None) -> Dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_structs(cfg, batch, max_len, page_len,
                                      pool_pages))


def _decode_attn(p: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
                 blk: BlockCfg, pos: jax.Array, packed: Optional[Dict] = None,
                 impl: Optional[str] = None,
                 page_table: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Dict]:
    """``packed`` maps projection names (wq/wk/wv/wo) to ``BitmapWeight``s;
    present entries stream compressed through kernels/ops (serve time).

    ``page_table`` ((B, page_slots) int32, physical page ids, 0 = the
    reserved trash page) switches the cache onto the paged layout: the
    K/V write scatters through the table into the page pool and attention
    gathers the slot's pages back into one contiguous view (see
    ``repro.serve.paging``).
    """
    b, _, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    pk = packed or {}
    xn = L.norm(x, p.get("norm"), cfg.norm)
    q = L.matmul_or_bitmap(xn, p["wq"], pk.get("wq"), impl).reshape(
        b, 1, h, hd)
    k = L.matmul_or_bitmap(xn, p["wk"], pk.get("wk"), impl).reshape(
        b, 1, kv, hd)
    v = L.matmul_or_bitmap(xn, p["wv"], pk.get("wv"), impl).reshape(
        b, 1, kv, hd)
    if cfg.qk_norm:
        q = L.norm(q, p["q_norm"], "rmsnorm")
        k = L.norm(k, p["k_norm"], "rmsnorm")
    pos = jnp.asarray(pos)
    posv = jnp.broadcast_to(pos, (b,)) if pos.ndim == 0 else pos
    posb = posv[:, None]
    q = L.rope(q, posb, cfg.rope_theta)
    k = L.rope(k, posb, cfg.rope_theta)
    if page_table is not None:
        plen = cache["k"].shape[1]
        cap, ring = paged_addressing(page_table.shape[1], plen, blk.window)
        slot = (posv % cap) if ring else jnp.clip(posv, 0, cap - 1)
        k_cache, v_cache = L.paged_kv_update(
            cache["k"], cache["v"], k, v, page_table, slot)
        k_att = L.paged_gather(k_cache, page_table)
        v_att = L.paged_gather(v_cache, page_table)
    else:
        c = cache["k"].shape[1]
        ring = blk.window is not None and c == blk.window
        if pos.ndim == 0:
            slot = (pos % c) if ring else pos
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        else:
            # per-slot positions (continuous batching): each batch row
            # writes its own cache line, so the update is a batched scatter
            slot = (posv % c) if ring else jnp.clip(posv, 0, c - 1)
            k_cache, v_cache = L.slot_kv_update(cache["k"], cache["v"],
                                                k, v, slot)
        k_att, v_att = k_cache, v_cache
    o = L.decode_attention(q, k_att, v_att, pos, window=blk.window,
                           ring=ring)
    out = L.matmul_or_bitmap(o.reshape(b, 1, h * hd), p["wo"],
                             pk.get("wo"), impl)
    return out, {"k": k_cache, "v": v_cache}


def _prefill_attn(p: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
                  blk: BlockCfg, pos: jax.Array, lens: jax.Array,
                  packed: Optional[Dict] = None,
                  impl: Optional[str] = None,
                  page_table: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Dict]:
    """Chunked-prefill attention: C tokens per slot through one call.

    x: (B, C, D) chunk hidden states; pos: (B,) start position of each
    slot's chunk; lens: (B,) valid tokens this call (rows past their
    length are padding lanes whose cache writes are masked off).

    The q/k/v/o projections run batched over the whole chunk (M = B·C
    rows through ``matmul_or_bitmap`` — where the compressed weight
    stream amortizes), while the cache write + attention core scan the
    chunk one token at a time.  Each inner step writes token t's K/V
    line and then attends token t against the cache — exactly the state
    the decode path would see at that position, so chunked prefill is
    bit-identical to teacher-forcing the prompt through decode steps
    (ring wraps, windows and paging included, with no layout-dependent
    re-association of the softmax).
    """
    b, c_chunk, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    pk = packed or {}
    xn = L.norm(x, p.get("norm"), cfg.norm)
    q = L.matmul_or_bitmap(xn, p["wq"], pk.get("wq"), impl).reshape(
        b, c_chunk, h, hd)
    k = L.matmul_or_bitmap(xn, p["wk"], pk.get("wk"), impl).reshape(
        b, c_chunk, kv, hd)
    v = L.matmul_or_bitmap(xn, p["wv"], pk.get("wv"), impl).reshape(
        b, c_chunk, kv, hd)
    if cfg.qk_norm:
        q = L.norm(q, p["q_norm"], "rmsnorm")
        k = L.norm(k, p["k_norm"], "rmsnorm")
    posv = jnp.asarray(pos)
    posb = posv[:, None] + jnp.arange(c_chunk)[None, :]      # (B, C)
    q = L.rope(q, posb, cfg.rope_theta)
    k = L.rope(k, posb, cfg.rope_theta)
    if page_table is not None:
        plen = cache["k"].shape[1]
        cap, ring = paged_addressing(page_table.shape[1], plen, blk.window)
    else:
        cap = cache["k"].shape[1]
        ring = blk.window is not None and cap == blk.window

    def tok_step(carry, xs):
        k_cache, v_cache = carry
        q_t, k_t, v_t, t = xs                   # (B, H/Hkv, hd), scalar t
        pos_t = posv + t
        valid = t < lens
        slot = (pos_t % cap) if ring else jnp.clip(pos_t, 0, cap - 1)
        if page_table is not None:
            k_cache, v_cache = L.paged_kv_update(
                k_cache, v_cache, k_t[:, None], v_t[:, None], page_table,
                slot, valid=valid)
            k_att = L.paged_gather(k_cache, page_table)
            v_att = L.paged_gather(v_cache, page_table)
        else:
            k_cache, v_cache = L.slot_kv_update(
                k_cache, v_cache, k_t[:, None], v_t[:, None], slot,
                valid=valid)
            k_att, v_att = k_cache, v_cache
        o = L.decode_attention(q_t[:, None], k_att, v_att, pos_t,
                               window=blk.window, ring=ring)
        return (k_cache, v_cache), o[:, 0]

    (k_cache, v_cache), outs = jax.lax.scan(
        tok_step, (cache["k"], cache["v"]),
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         jnp.arange(c_chunk)))
    o = outs.swapaxes(0, 1)                                  # (B, C, Hq, hd)
    out = L.matmul_or_bitmap(o.reshape(b, c_chunk, h * hd), p["wo"],
                             pk.get("wo"), impl)
    return out, {"k": k_cache, "v": v_cache}


def prefill_hidden(params: Dict, cache: Dict, cfg: ModelConfig,
                   tokens: jax.Array, pos: jax.Array, lens: jax.Array,
                   embeds: Optional[jax.Array] = None,
                   packed: Optional[Dict] = None,
                   impl: Optional[str] = None,
                   page_tables: Optional[Dict] = None
                   ) -> Tuple[jax.Array, Dict]:
    """One chunked-prefill call: C prompt tokens per slot in one pass.

    tokens: (B, C) (or embeds (B, C, D)); pos: (B,) chunk start
    positions; lens: (B,) valid tokens per slot (0 = the slot sits this
    call out; its lane is padding and writes nothing).  Returns (hidden
    (B, C, D) after the final norm, new cache) — the C KV lines per slot
    are written into the cache, which is the whole point: after the last
    chunk the slot joins the decode batch at position ``len(prompt) - 1``
    with its prompt cache fully resident.

    Projections and MLPs dispatch batched over the chunk (M = C through
    the packed ``matmul_or_bitmap`` path); MoE FFNs dispatch per token
    (chunk rows folded into the batch dim) so expert capacity — which
    scales with sequence length — matches the decode path token for
    token.  Recurrent mixers (mamba/rwkv) have no chunked path yet; the
    engine keeps teacher-forcing for those archs with a recorded reason.
    """
    x = embed_inputs(params, cfg, tokens, embeds)
    b, c_chunk, d = x.shape

    def period_fn(x, xs):
        period_params, period_cache, period_packed = xs
        new_cache = {}
        for i, blk in enumerate(cfg.pattern):
            bp = period_params[f"b{i}"]
            pc = period_cache[f"b{i}"]
            pw = (period_packed or {}).get(f"b{i}") or {}
            nc = {}
            if blk.mixer == "attn":
                o, nc = _prefill_attn(bp["attn"], x, pc, cfg, blk, pos,
                                      lens, packed=pw.get("attn"),
                                      impl=impl,
                                      page_table=(page_tables or {}).get(
                                          f"b{i}"))
                x = x + o
            else:
                raise NotImplementedError(
                    f"chunked prefill has no {blk.mixer} path; the engine "
                    f"falls back to teacher-forcing for this arch")
            if blk.ffn == "mlp":
                xn = L.norm(x, bp["mlp"].get("norm"), cfg.norm)
                x = x + L.mlp(bp["mlp"], xn, cfg, packed=pw.get("mlp"),
                              impl=impl)
            elif blk.ffn == "moe":
                xn = L.norm(x, bp["moe"].get("norm"), cfg.norm)
                # per-token dispatch: capacity = ceil(S·k·cf/E) depends on
                # the sequence length, so a (B, C) chunk through one MoE
                # call would drop tokens differently than C decode steps —
                # folding the chunk into the batch dim keeps the dispatch
                # (and the tokens) bit-identical to decode
                mo = L.moe_ffn(bp["moe"], xn.reshape(b * c_chunk, 1, d),
                               cfg, packed=pw.get("moe"), impl=impl)
                x = x + mo.reshape(b, c_chunk, d)
            elif blk.ffn == "rwkv_cm":
                raise NotImplementedError(
                    "chunked prefill has no rwkv_cm path; the engine "
                    "falls back to teacher-forcing for this arch")
            new_cache[f"b{i}"] = nc
        return x, new_cache

    x, new_cache = jax.lax.scan(period_fn, x,
                                (params["blocks"], cache, packed))
    return L.norm(x, params.get("final_norm"), cfg.norm), new_cache


def decode_hidden(params: Dict, cache: Dict, cfg: ModelConfig,
                  tokens: Optional[jax.Array], pos: jax.Array,
                  embeds: Optional[jax.Array] = None,
                  packed: Optional[Dict] = None,
                  impl: Optional[str] = None,
                  page_tables: Optional[Dict] = None
                  ) -> Tuple[jax.Array, Dict]:
    """One decode step up to (and including) the final norm — no LM head.

    tokens: (B, 1) (or embeds (B, 1, D)); pos: scalar shared position or a
    (B,) vector of per-slot positions (continuous batching).  Returns
    (hidden (B, 1, D), new cache).  Scans over periods, carrying the
    hidden state and threading each period's cache slice through as
    scan xs/ys.

    ``packed`` mirrors ``params["blocks"]`` with period-stacked
    ``BitmapWeight`` leaves — 2-D projections (attention, MLP, MoE
    router, mamba/rwkv mixer and channel-mix GEMMs) plus group-stacked
    MoE expert tensors and rwkv's mix_B (or None where a tensor fell
    back to dense — see repro.serve.packed); the scan slices off the
    period axis so each iteration's projections stream bitmap-compressed
    through kernels/ops.

    ``page_tables`` (``{bname: (B, page_slots) int32}``) switches attention
    blocks onto the paged-cache layout.  Tables are shared by all periods
    of a block (the physical-page axis of each pool already carries the
    period dim), so they ride into the scan body by closure, not as xs.
    """
    x = embed_inputs(params, cfg, tokens, embeds)
    b = x.shape[0]

    def period_fn(x, xs):
        period_params, period_cache, period_packed = xs
        new_cache = {}
        for i, blk in enumerate(cfg.pattern):
            bp = period_params[f"b{i}"]
            pc = period_cache[f"b{i}"]
            pw = (period_packed or {}).get(f"b{i}") or {}
            nc = {}
            if blk.mixer == "attn":
                o, nc = _decode_attn(bp["attn"], x, pc, cfg, blk, pos,
                                     packed=pw.get("attn"), impl=impl,
                                     page_table=(page_tables or {}).get(
                                         f"b{i}"))
                x = x + o
            elif blk.mixer == "mamba":
                xn = L.norm(x, bp["mamba"].get("norm"), cfg.norm)
                o, st = ssm.mamba_decode(bp["mamba"], xn,
                                         {"h": pc["h"], "conv": pc["conv"]},
                                         cfg, packed=pw.get("mamba"),
                                         impl=impl)
                x = x + o
                nc = st
            elif blk.mixer == "rwkv":
                xn = L.norm(x, bp["rwkv"].get("norm"), cfg.norm)
                o, st = ssm.rwkv_decode(bp["rwkv"], xn,
                                        {"s": pc["s"],
                                         "x_prev": pc["x_prev"]}, cfg,
                                        packed=pw.get("rwkv"), impl=impl)
                x = x + o
                nc = st
            if blk.ffn == "mlp":
                xn = L.norm(x, bp["mlp"].get("norm"), cfg.norm)
                x = x + L.mlp(bp["mlp"], xn, cfg, packed=pw.get("mlp"),
                              impl=impl)
            elif blk.ffn == "moe":
                xn = L.norm(x, bp["moe"].get("norm"), cfg.norm)
                x = x + L.moe_ffn(bp["moe"], xn, cfg, packed=pw.get("moe"),
                                  impl=impl)
            elif blk.ffn == "rwkv_cm":
                xn = L.norm(x, bp["rwkv_cm"].get("norm"), cfg.norm)
                x = x + ssm.rwkv_channel_mix(bp["rwkv_cm"], xn, cfg,
                                             x_prev=pc["cm_x_prev"][:, None],
                                             packed=pw.get("rwkv_cm"),
                                             impl=impl)
                nc["cm_x_prev"] = xn[:, 0]
            new_cache[f"b{i}"] = nc
        return x, new_cache

    x, new_cache = jax.lax.scan(period_fn, x,
                                (params["blocks"], cache, packed))
    return L.norm(x, params.get("final_norm"), cfg.norm), new_cache


def head_logits(params: Dict, cfg: ModelConfig, hidden: jax.Array,
                lm_weight=None, lm_impl: Optional[str] = None) -> jax.Array:
    """LM head over (B, D) hidden states -> (B, V) f32 logits.

    ``lm_weight`` (a ``BitmapWeight``) switches the head matmul onto the
    bitmap-compressed path through ``kernels/ops.bitmap_spmm`` — the
    serving engine packs the head once and streams it compressed, so the
    dominant decode weight-traffic term runs the paper's format end-to-end.
    """
    if lm_weight is None:
        w = lm_head_weight(params, cfg).astype(hidden.dtype)
        logits = (hidden @ w).astype(jnp.float32)
    else:
        from repro.kernels import ops
        # the kernel's small-M path handles decode batches below the
        # 128-row tile (rows round up to the sublane multiple, not 128)
        logits = ops.bitmap_spmm(hidden, lm_weight,
                                 impl=lm_impl).astype(jnp.float32)
    from repro.models.perf_flags import baseline_mode
    if not baseline_mode():
        # §Perf: keep decode logits vocab-sharded — otherwise GSPMD
        # gathers the whole embedding table per device (~12 GB/step for
        # gemma3-12b's 262k vocab).
        logits = shard_utils.hint(logits, "batch", "model")
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def decode_step(params: Dict, cache: Dict, cfg: ModelConfig,
                tokens: Optional[jax.Array], pos: jax.Array,
                embeds: Optional[jax.Array] = None, lm_weight=None,
                packed: Optional[Dict] = None,
                lm_impl: Optional[str] = None,
                page_tables: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict]:
    """One decode step + LM head: (logits (B, V), new cache).

    ``packed`` (block-tree of period-stacked ``BitmapWeight``s) and
    ``lm_weight`` together put the whole per-step weight stream —
    attention q/k/v/o, MLP gate/up/down, LM head — on the
    bitmap-compressed kernels/ops path; ``page_tables`` routes the KV
    cache through the paged layout (see ``decode_hidden``).
    """
    x, new_cache = decode_hidden(params, cache, cfg, tokens, pos,
                                 embeds=embeds, packed=packed, impl=lm_impl,
                                 page_tables=page_tables)
    return head_logits(params, cfg, x[:, 0], lm_weight, lm_impl), new_cache
