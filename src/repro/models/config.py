"""Model configuration for the unified decoder stack.

A model is ``num_periods`` repetitions of a heterogeneous ``pattern`` of
blocks (mixer + ffn); homogeneous archs use a period of one block.  The
pattern mechanism expresses gemma3's 5 local : 1 global attention, jamba's
1:7 attn:mamba interleave with MoE every other layer, etc., while keeping
``lax.scan`` over periods (O(1) HLO depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One block of the repeating pattern."""

    mixer: str = "attn"          # attn | mamba | rwkv
    ffn: str = "mlp"             # mlp | moe | rwkv_cm | none
    window: Optional[int] = None  # sliding-window size for local attention


@dataclasses.dataclass(frozen=True)
class SparsityCfg:
    """The paper's technique as a framework feature."""

    enabled: bool = False
    sparsity: float = 0.75        # global L1 target (paper: 75 %)
    format: str = "bitmap"        # bitmap | block — serving weight format
    block: Tuple[int, int] = (128, 128)
    masked_training: bool = True  # keep pruned weights at zero during training


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    num_layers: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[BlockCfg, ...] = (BlockCfg(),)
    head_dim: Optional[int] = None
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    rwkv_head_dim: int = 64
    # misc
    norm: str = "rmsnorm"         # rmsnorm | ln_nonparam | ln
    qk_norm: bool = False
    act: str = "silu"             # silu | gelu | relu
    rope_theta: float = 10_000.0
    max_seq_len: int = 131_072
    tie_embeddings: bool = True
    embed_scale: bool = False     # gemma-style sqrt(d) embedding scale
    logit_softcap: Optional[float] = None
    # modality frontend stub: number of precomputed embedding positions
    frontend: Optional[str] = None   # None | "patches" | "frames"
    frontend_len: int = 0            # patch positions prepended (vlm)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # technique
    sparsity: SparsityCfg = SparsityCfg()
    # training-memory knobs
    remat: bool = True
    loss_chunk: int = 512         # sequence chunk for the CE loss
    scan_layers: bool = True

    def __post_init__(self):
        assert self.num_layers % len(self.pattern) == 0, (
            self.name, self.num_layers, len(self.pattern))
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_ssm_or_hybrid(self) -> bool:
        return any(b.mixer in ("mamba", "rwkv") for b in self.pattern)

    @property
    def fully_quadratic(self) -> bool:
        """True if every mixer is full (global) attention."""
        return all(b.mixer == "attn" and b.window is None
                   for b in self.pattern)

    def param_count(self) -> int:
        """Exact parameter count from the shape inventory."""
        from repro.models.model import param_shapes  # lazy, avoids cycle
        shapes = param_shapes(self)
        import math
        total = 0
        for leaf in _tree_leaves(shapes):
            total += math.prod(leaf)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if self.num_experts == 0:
            return self.param_count()
        from repro.models.model import param_shapes
        import math
        shapes = param_shapes(self)
        total = 0
        for path, leaf in _tree_items(shapes):
            n = math.prod(leaf)
            if "moe" in path and "router" not in path:
                n = n * self.top_k // self.num_experts
            total += n
        return total


def _tree_leaves(d, out=None):
    out = [] if out is None else out
    for v in d.values():
        if isinstance(v, dict):
            _tree_leaves(v, out)
        else:
            out.append(v)
    return out


def _tree_items(d, prefix="", out=None):
    out = [] if out is None else out
    for k, v in d.items():
        p = f"{prefix}/{k}"
        if isinstance(v, dict):
            _tree_items(v, p, out)
        else:
            out.append((p, v))
    return out
