"""State-space mixers: Mamba (selective SSM, jamba) and RWKV6 (Finch).

Both are implemented with a chunked-scan structure: an outer ``lax.scan``
over time chunks carries the recurrent state (O(L/C) saved residuals under
remat), and the intra-chunk recurrence runs vectorised (associative scan for
Mamba's elementwise state; a short sequential scan for RWKV6's matrix
state).  Single-step ``*_decode`` variants serve the decode shapes — these
archs are why the 500k-context cells are runnable at all (O(1) state vs a
KV cache).

The paper's EIM/SIDR applies to the projection GEMMs of both mixers; the
recurrences themselves are not GEMMs (DESIGN.md §4).  At serve time the
``*_decode`` cells (and the RWKV channel-mix) take a ``packed`` dict
mapping projection names to ``BitmapWeight``s so those GEMMs stream
bitmap-compressed through ``layers.matmul_or_bitmap`` — the 2-D mixer
projections ride the same period-stacked layout as attention/MLP, and
RWKV6's 5-way lerp stack ``mix_B`` rides the group-stacked expert
layout (see repro.serve.packed / DESIGN_PACKED.md).  The full-sequence
``*_mix`` forwards (training path) stay dense.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import group_norm_heads, matmul_or_bitmap

# ---------------------------------------------------------------- Mamba ----


def _ssm_chunk(a: jax.Array, bx: jax.Array, h0: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + bx_t via associative scan within a chunk.

    a, bx: (B, C, dI, N); h0: (B, dI, N).  Returns (h_all, h_last).
    """
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_cum * h0[:, None] + b_cum
    return h, h[:, -1]


def mamba_mix(params: dict, x: jax.Array, cfg: ModelConfig,
              chunk: int = 256) -> jax.Array:
    """Selective SSM (Mamba-1) forward. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    dtr = cfg.mamba_dt_rank
    dt_ = x.dtype

    xz = x @ params["in_proj"].astype(dt_)               # (B, S, 2*dI)
    xs, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time
    conv_w = params["conv_w"].astype(dt_)                # (dI, K)
    kk = conv_w.shape[-1]
    pad = jnp.pad(xs, ((0, 0), (kk - 1, 0), (0, 0)))
    xs = sum(pad[:, i:i + s] * conv_w[:, i] for i in range(kk))
    xs = jax.nn.silu(xs + params["conv_b"].astype(dt_))

    # data-dependent (selective) parameters
    dbc = xs @ params["x_proj"].astype(dt_)              # (B, S, dtr+2N)
    dt, bmat, cmat = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(dt_)
                         + params["dt_bias"].astype(dt_))  # (B, S, dI)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))    # (dI, N)

    dt32 = dt.astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * a)                    # (B, S, dI, N)
    dbx = (dt32[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
           * xs.astype(jnp.float32)[..., None])          # (B, S, dI, N)

    n_chunks = -(-s // chunk)
    pad_t = n_chunks * chunk - s
    if pad_t:
        da = jnp.pad(da, ((0, 0), (0, pad_t), (0, 0), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    da = da.reshape(b, n_chunks, chunk, di, n).swapaxes(0, 1)
    dbx = dbx.reshape(b, n_chunks, chunk, di, n).swapaxes(0, 1)

    def step(h, xs_):
        a_c, bx_c = xs_
        h_all, h_last = _ssm_chunk(a_c, bx_c, h)
        return h_last, h_all

    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, h_seq = jax.lax.scan(step, h0, (da, dbx))
    h_seq = h_seq.swapaxes(0, 1).reshape(b, n_chunks * chunk, di, n)[:, :s]

    y = jnp.einsum("bsdn,bsn->bsd", h_seq, cmat.astype(jnp.float32))
    y = y.astype(dt_) + xs * params["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"].astype(dt_)


def mamba_decode(params: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                 packed: Optional[dict] = None, impl: Optional[str] = None
                 ) -> Tuple[jax.Array, dict]:
    """One-token Mamba step. x: (B, 1, D); state: {"h": (B,dI,N),
    "conv": (B, K-1, dI)}.  ``packed`` maps in/x/dt/out projection names
    to ``BitmapWeight``s (serve-time compressed streaming)."""
    pk = packed or {}
    b, _, d = x.shape
    n = cfg.mamba_d_state
    dtr = cfg.mamba_dt_rank
    dt_ = x.dtype

    xz = matmul_or_bitmap(x[:, 0], params["in_proj"], pk.get("in_proj"),
                          impl)
    xs, z = jnp.split(xz, 2, axis=-1)                    # (B, dI)

    conv_w = params["conv_w"].astype(dt_)                # (dI, K)
    hist = jnp.concatenate([state["conv"], xs[:, None]], 1)  # (B, K, dI)
    xs_c = jnp.einsum("bkd,dk->bd", hist, conv_w)
    xs_c = jax.nn.silu(xs_c + params["conv_b"].astype(dt_))
    new_conv = hist[:, 1:]

    dbc = matmul_or_bitmap(xs_c, params["x_proj"], pk.get("x_proj"), impl)
    dt, bmat, cmat = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(matmul_or_bitmap(dt, params["dt_proj"],
                                          pk.get("dt_proj"), impl)
                         + params["dt_bias"].astype(dt_))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)  # (B, dI, N)
    dbx = (dt.astype(jnp.float32)[..., None]
           * bmat.astype(jnp.float32)[:, None, :]
           * xs_c.astype(jnp.float32)[..., None])
    h = da * state["h"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, cmat.astype(jnp.float32)).astype(dt_)
    y = y + xs_c * params["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    out = matmul_or_bitmap(y, params["out_proj"], pk.get("out_proj"),
                           impl)[:, None]
    return out, {"h": h, "conv": new_conv}

# ---------------------------------------------------------------- RWKV6 ----


def _rwkv_tokens(params: dict, x: jax.Array, x_prev: jax.Array,
                 cfg: ModelConfig, packed: Optional[dict] = None,
                 impl: Optional[str] = None):
    """Shared r/k/v/w/g preparation. x: (B, S, D); x_prev: (B, S, D) is x
    shifted right by one token (data-dependent token-shift, Finch).
    ``packed`` (decode path only) streams the projection GEMMs — w_r/k/v/g,
    decay_A/decay_B, mix_A and the 5-way group-stacked mix_B —
    bitmap-compressed."""
    pk = packed or {}
    dt_ = x.dtype
    diff = x_prev - x
    # low-rank data-dependent lerp amounts for r,k,v,w,g
    lora = jnp.tanh(matmul_or_bitmap(x, params["mix_A"], pk.get("mix_A"),
                                     impl))              # (B,S,5*rank)
    lora = lora.reshape(*x.shape[:-1], 5, -1)
    if pk.get("mix_B") is None:
        dyn = jnp.einsum("bsfr,frd->bsfd", lora, params["mix_B"].astype(dt_))
    else:
        # group-stacked dispatch: the 5 lerp channels are 5 independent
        # (rank, D) GEMMs — the same layout as an MoE expert stack
        from repro.kernels import ops
        b_, s_, f_, r_ = lora.shape
        lx = jnp.moveaxis(lora, 2, 0).reshape(f_, b_ * s_, r_)
        dyn = jnp.moveaxis(
            ops.bitmap_spmm_grouped(lx, pk["mix_B"], impl=impl)
            .reshape(f_, b_, s_, -1), 0, 2)
    mix = params["mix_mu"].astype(dt_) + dyn             # (B,S,5,D)
    xr, xk, xv, xw, xg = [x + diff * mix[..., i, :] for i in range(5)]

    r = matmul_or_bitmap(xr, params["w_r"], pk.get("w_r"), impl)
    k = matmul_or_bitmap(xk, params["w_k"], pk.get("w_k"), impl)
    v = matmul_or_bitmap(xv, params["w_v"], pk.get("w_v"), impl)
    g = jax.nn.silu(matmul_or_bitmap(xg, params["w_g"], pk.get("w_g"),
                                     impl))
    # data-dependent decay (the headline Finch feature)
    ww = params["w0"].astype(jnp.float32) + matmul_or_bitmap(
        jnp.tanh(matmul_or_bitmap(xw, params["decay_A"],
                                  pk.get("decay_A"), impl)
                 ).astype(jnp.float32),
        params["decay_B"], pk.get("decay_B"), impl)
    w = jnp.exp(-jnp.exp(ww))                            # (B,S,D) in (0,1)
    return r, k, v, w, g


def rwkv_mix(params: dict, x: jax.Array, cfg: ModelConfig,
             chunk: int = 128) -> jax.Array:
    """RWKV6 time-mix. x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    h = cfg.rwkv_heads
    hd = cfg.rwkv_head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _rwkv_tokens(params, x, x_prev, cfg)

    def heads(t):
        return t.reshape(b, s, h, hd).astype(jnp.float32)

    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(w)
    u = params["u"].astype(jnp.float32)                  # (H, hd)

    n_chunks = -(-s // chunk)
    pad_t = n_chunks * chunk - s
    if pad_t:
        r_ = jnp.pad(r_, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        k_ = jnp.pad(k_, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v_ = jnp.pad(v_, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        w_ = jnp.pad(w_, ((0, 0), (0, pad_t), (0, 0), (0, 0)),
                     constant_values=1.0)
    resh = lambda t: t.reshape(b, n_chunks, chunk, h, hd).swapaxes(0, 1)
    r_, k_, v_, w_ = resh(r_), resh(k_), resh(v_), resh(w_)

    def chunk_step(state, xs_):
        rc, kc, vc, wc = xs_                             # (B, C, H, hd)

        def tok(st, ts):
            rt, kt, vt, wt = ts                          # (B, H, hd)
            kv = kt[..., :, None] * vt[..., None, :]     # (B,H,hd,hd)
            out = jnp.einsum("bhk,bhkv->bhv", rt,
                             st + u[None, :, :, None] * kv)
            st = wt[..., :, None] * st + kv
            return st, out

        st, outs = jax.lax.scan(
            tok, state,
            (rc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             wc.swapaxes(0, 1)))
        return st, outs.swapaxes(0, 1)                   # (B, C, H, hd)

    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, out = jax.lax.scan(chunk_step, s0, (r_, k_, v_, w_))
    out = out.swapaxes(0, 1).reshape(b, n_chunks * chunk, h * hd)[:, :s]
    out = group_norm_heads(out.astype(x.dtype), params["gn_scale"], h)
    out = out * g
    return out @ params["w_o"].astype(x.dtype)


def rwkv_decode(params: dict, x: jax.Array, state: dict, cfg: ModelConfig,
                packed: Optional[dict] = None, impl: Optional[str] = None
                ) -> Tuple[jax.Array, dict]:
    """One-token RWKV6 step. state: {"s": (B,H,hd,hd), "x_prev": (B, D)}.
    ``packed`` streams the mixer's projection GEMMs bitmap-compressed
    (serve time; see repro.serve.packed)."""
    b, _, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    r, k, v, w, g = _rwkv_tokens(params, x, state["x_prev"][:, None], cfg,
                                 packed=packed, impl=impl)
    rt = r[:, 0].reshape(b, h, hd).astype(jnp.float32)
    kt = k[:, 0].reshape(b, h, hd).astype(jnp.float32)
    vt = v[:, 0].reshape(b, h, hd).astype(jnp.float32)
    wt = w[:, 0].reshape(b, h, hd).astype(jnp.float32)
    u = params["u"].astype(jnp.float32)
    kv = kt[..., :, None] * vt[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", rt, state["s"] + u[..., None] * kv)
    new_s = wt[..., :, None] * state["s"] + kv
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    out = group_norm_heads(out, params["gn_scale"], h) * g
    return matmul_or_bitmap(out, params["w_o"], (packed or {}).get("w_o"),
                            impl), {"s": new_s, "x_prev": x[:, 0]}


def rwkv_channel_mix(params: dict, x: jax.Array, cfg: ModelConfig,
                     x_prev: jax.Array | None = None,
                     packed: Optional[dict] = None,
                     impl: Optional[str] = None) -> jax.Array:
    """RWKV channel-mix FFN (squared-relu). Works for (B,S,D) and decode.
    ``packed`` streams cm_k/cm_v/cm_r bitmap-compressed (serve time)."""
    pk = packed or {}
    dt_ = x.dtype
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mu = params["cm_mu"].astype(dt_)                     # (2, D)
    xk = x + (x_prev - x) * mu[0]
    xr = x + (x_prev - x) * mu[1]
    k = jnp.square(jax.nn.relu(matmul_or_bitmap(xk, params["cm_k"],
                                                pk.get("cm_k"), impl)))
    return jax.nn.sigmoid(
        matmul_or_bitmap(xr, params["cm_r"], pk.get("cm_r"), impl)
    ) * matmul_or_bitmap(k, params["cm_v"], pk.get("cm_v"), impl)
