"""A/B switch between the naive-baseline lowering and the optimized one.

``REPRO_PERF_MODE=baseline`` reproduces the pre-hillclimb lowering
(EXPERIMENTS.md §Perf "before" rows) so both variants can be measured with
the same HLO counters: un-sharded/un-remat'd loss, global-argsort MoE
dispatch, and repeat-materialised GQA.  Default: optimized.
"""
from __future__ import annotations

import os


def baseline_mode() -> bool:
    return os.environ.get("REPRO_PERF_MODE", "").lower() == "baseline"
