"""Unified decoder model stack covering all assigned architectures."""
from repro.models.config import BlockCfg, ModelConfig, SparsityCfg
from repro.models.model import (attn_capacity, cache_structs,
                                decode_hidden, decode_step, forward,
                                head_logits, init_cache, init_params,
                                lm_loss, loss_fn, paged_layout,
                                param_shapes, param_structs)

__all__ = [
    "BlockCfg", "ModelConfig", "SparsityCfg", "attn_capacity",
    "cache_structs", "decode_hidden", "decode_step", "forward",
    "head_logits", "init_cache", "init_params", "lm_loss", "loss_fn",
    "paged_layout", "param_shapes", "param_structs",
]
