"""Pallas TPU API compatibility across jax versions.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
jax releases; the kernels import the alias from here so they run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
