"""Pallas TPU kernel: dense × bitmap-compressed-sparse matmul (EIM on TPU).

TPU adaptation of the paper's EIM + SIDR (DESIGN.md §2):

* weights travel HBM→VMEM in the paper's bitmap format (packed bits + packed
  non-zero values + per-row start offsets) — HBM traffic falls by ≈ the
  density ratio, the analogue of the 86 % SRAM-access cut;
* inside VMEM each tile is decompressed with the EIM re-sort
  (``row_start[i] + rank_within_row`` = IMId/masked-bitmap logic of §II-C)
  and fed dense to the MXU;
* the activation tile is fetched once per (i, k) and *reused across the
  whole output-column grid dimension* (its BlockSpec index map ignores j) —
  the SIDR row-broadcast; the compressed weight tile is likewise reused
  across the output-row dimension — the SIDR column-broadcast;
* output-stationary f32 accumulator in VMEM across the K grid axis.

Grid: (M/BM, N/BN, K/BK), K innermost (sequential accumulation).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.sparse.format import _TILE_ND, BitmapWeight


def _decompress_tile(bits_packed, values, row_start, bk: int, bn: int,
                     budget: int, dtype):
    """EIM re-sort inside VMEM: packed tile -> dense (BK, BN)."""
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, 1, 8), 2)
    bits = (bits_packed[:, :, None] >> shifts) & jnp.uint8(1)
    bits = bits.reshape(bk, bn).astype(jnp.int32)
    rank = jnp.cumsum(bits, axis=1) - 1              # rank within tile row
    slot = jnp.clip(row_start[:, None] + rank, 0, budget - 1)
    vals = jnp.take(values, slot.reshape(-1), axis=0).reshape(bk, bn)
    return jnp.where(bits != 0, vals, jnp.zeros((), dtype)).astype(dtype)


def _kernel(x_ref, bits_ref, vals_ref, rows_ref, o_ref, acc_ref, *,
            bk: int, bn: int, budget: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = _decompress_tile(bits_ref[0, 0], vals_ref[0, 0], rows_ref[0, 0],
                              bk, bn, budget, x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w_tile,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _sublane(dtype) -> int:
    """Minimum second-to-minor tile size for the dtype (f32: 8, bf16: 16)."""
    return {2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def bitmap_spmm(x: jax.Array, w: BitmapWeight, *, bm: int = 128,
                interpret: bool = True, out_dtype=None) -> jax.Array:
    """Compute ``x @ W`` with W stored bitmap-compressed.

    x: (M, K); W logical shape (K, N).  Returns (M, N).

    Decode-shaped small-M path: any M in 1..bm (and any M not a multiple
    of ``bm``) is accepted — the row tile shrinks to M rounded up to the
    dtype's sublane multiple, the handful of zero pad rows accumulate
    zeros and their stores are sliced away, instead of the old behaviour
    of requiring the caller to pad a 4-row decode batch 32× up to 128.
    """
    m, k = x.shape
    kk, n = w.shape
    assert k == kk, (x.shape, w.shape)
    bk, bn = w.block
    kt, nt = k // bk, n // bn
    out_dtype = out_dtype or x.dtype
    budget = w.budget

    if m % bm != 0:
        bm = min(bm, _round_up(m, _sublane(x.dtype)))
        m_pad = _round_up(m, bm)
        if m_pad != m:
            xp = jnp.pad(x, ((0, m_pad - m), (0, 0)))
            return bitmap_spmm(xp, w, bm=bm, interpret=interpret,
                               out_dtype=out_dtype)[:m]

    grid = (m // bm, nt, kt)
    kernel = functools.partial(_kernel, bk=bk, bn=bn, budget=budget, n_k=kt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kq: (i, kq)),
            pl.BlockSpec((1, 1, bk, bn // 8), lambda i, j, kq: (kq, j, 0, 0)),
            pl.BlockSpec((1, 1, budget), lambda i, j, kq: (kq, j, 0)),
            pl.BlockSpec((1, 1, bk), lambda i, j, kq: (kq, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="bitmap_spmm",
    )(x, w.packed_bits, w.values, w.row_start)


def group_slice(w: BitmapWeight, g: int) -> BitmapWeight:
    """The g-th (K, N) member of a group-stacked ``BitmapWeight``.

    Group-stacked weights (MoE expert stacks, RWKV lerp stacks — see
    ``sparse.format.pack_bitmap_experts``) carry one leading G axis per
    array leaf at dispatch time (the period axis has already been
    scanned off); ``shape``/``block``/``budget`` are shared, so a slice
    is a plain per-matrix ``BitmapWeight`` the kernel accepts as-is.
    The ``dense_cache`` is deliberately not sliced through: the Pallas
    path never reads it (it exists only for the xla oracle dispatch,
    which consumes the stacked cache whole).
    """
    return BitmapWeight(packed_bits=w.packed_bits[g], values=w.values[g],
                        row_start=w.row_start[g], shape=w.shape,
                        block=w.block, shard=w.shard)


def shard_slice(w: BitmapWeight, s: int) -> BitmapWeight:
    """The s-th shard of a sharded ``BitmapWeight`` as a plain per-shard
    ``BitmapWeight`` (shard axis indexed away, per-shard logical shape).

    Column shards hold ``(K, N/S)``, row shards ``(K/S, N)`` — exact
    contiguous slices of the unsharded matrix, so the Pallas kernel
    consumes them as-is and the caller composes outputs (concat over N
    for col, sum over partial products for row).
    """
    assert w.shard is not None
    mode, shards = w.shard
    k, n = w.shape
    shape = (k, n // shards) if mode == "col" else (k // shards, n)

    def take(leaf, name):
        if leaf is None:
            return None
        return jnp.take(leaf, s, axis=leaf.ndim - _TILE_ND[name] - 1)

    return BitmapWeight(
        packed_bits=take(w.packed_bits, "packed_bits"),
        values=take(w.values, "values"),
        row_start=take(w.row_start, "row_start"),
        shape=shape, block=w.block,
        dense_cache=take(w.dense_cache, "dense_cache"))


def bitmap_spmm_grouped(x: jax.Array, w: BitmapWeight, *, bm: int = 128,
                        interpret: bool = True, out_dtype=None) -> jax.Array:
    """Per-group ``x[g] @ W_g`` over a group-stacked ``BitmapWeight``.

    x: (G, M, K); W logical shape (K, N) per group, array leaves leading
    with G.  Returns (G, M, N).  The group count is static (it is a
    weight-layout property), so the dispatch is an unrolled loop of G
    small-M ``bitmap_spmm`` calls, each streaming only its own group's
    compressed tiles.  Note the capacity-dispatch MoE caller runs this
    over *all* stored experts; the manifest's per-activated-expert HBM
    accounting models a gather dispatch that skips unselected groups
    (DESIGN_PACKED.md §6, modeled vs executed).
    """
    g = x.shape[0]
    assert g == w.values.shape[0], (x.shape, w.values.shape)
    return jnp.stack([
        bitmap_spmm(x[i], group_slice(w, i), bm=bm, interpret=interpret,
                    out_dtype=out_dtype) for i in range(g)])


def hbm_traffic_model(x_shape: Tuple[int, int], w: BitmapWeight,
                      bm: int = 128, itemsize: int = 2) -> dict:
    """Analytic HBM bytes of one bitmap_spmm call vs its dense equivalent.

    Activations are re-fetched once per output-column block (grid reuse
    pattern above); weights once per output-row block; outputs written once.
    Used by the roofline adjustment in benchmarks/roofline.py.

    Decode shapes (M < bm) follow the kernel's small-M path: one row
    block (mt = 1), so the whole compressed weight streams exactly once
    per step — the regime where the bitmap format pays off most.

    The ``components`` sub-dict breaks the totals into the per-tensor
    terms (activation re-fetches, output writes, sparse vs dense weight
    streams) that the serving traffic ledger (``serve/traffic.py``)
    attributes per role; the top-level keys keep their legacy meaning.
    """
    m, k = x_shape
    _, n = w.shape
    nt = n // w.block[1]
    mt = max(1, -(-m // bm))
    x_bytes = m * k * itemsize * nt
    out_bytes = m * n * itemsize
    w_sparse = w.hbm_bytes * mt
    w_dense = w.dense_bytes * mt
    return {
        "sparse_bytes": x_bytes + out_bytes + w_sparse,
        "dense_bytes": x_bytes + out_bytes + w_dense,
        "weight_compression": w.compression,
        "components": {
            "x_bytes": x_bytes,
            "out_bytes": out_bytes,
            "w_sparse_bytes": w_sparse,
            "w_dense_bytes": w_dense,
            "col_blocks": nt,
            "row_blocks": mt,
        },
    }
