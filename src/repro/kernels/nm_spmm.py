"""Pallas TPU kernel: dense × N:M-structured-sparse matmul (beyond paper).

Same fetch-once/broadcast structure as ``bitmap_spmm`` (activation tiles
reused across the output-column grid dim, compressed weights across the
output-row dim, output-stationary f32 accumulator over K), but the
decompression is M·N masked selects instead of a cumsum re-sort — fully
regular, no data-dependent indexing, which is exactly what the MXU wants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.sparse.nm import NmWeight


def _decompress(vals, idx, *, n: int, m: int, bk: int, bn: int, dtype):
    """(BKc, BN) packed -> (BK, BN) dense via M·N selects."""
    g = bk // m
    v = vals.reshape(g, n, bn)
    ix = idx.reshape(g, n, bn).astype(jnp.int32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (g, n, m, bn), 2)
    sel = ix[:, :, None, :] == pos
    dense = jnp.sum(jnp.where(sel, v[:, :, None, :], 0), axis=1)
    return dense.reshape(bk, bn).astype(dtype)


def _kernel(x_ref, v_ref, i_ref, o_ref, acc_ref, *, n, m, bk, bn, n_k):
    kq = pl.program_id(2)

    @pl.when(kq == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decompress(v_ref[0, 0], i_ref[0, 0], n=n, m=m, bk=bk, bn=bn,
                    dtype=x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(kq == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def nm_spmm(x: jax.Array, w: NmWeight, *, bm: int = 128,
            interpret: bool = True, out_dtype=None) -> jax.Array:
    """Compute ``x @ W`` with W N:M-compressed. x: (M, K) -> (M, N)."""
    mm, k = x.shape
    kk, n_cols = w.shape
    assert k == kk
    bk, bn = w.block
    kt, nt = k // bk, n_cols // bn
    bkc = w.values.shape[2]
    assert mm % bm == 0
    out_dtype = out_dtype or x.dtype

    return pl.pallas_call(
        functools.partial(_kernel, n=w.n_keep, m=w.m_group, bk=bk, bn=bn,
                          n_k=kt),
        grid=(mm // bm, nt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kq: (i, kq)),
            pl.BlockSpec((1, 1, bkc, bn), lambda i, j, kq: (kq, j, 0, 0)),
            pl.BlockSpec((1, 1, bkc, bn), lambda i, j, kq: (kq, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n_cols), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="nm_spmm",
    )(x, w.values, w.idx)
