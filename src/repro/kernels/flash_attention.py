"""Pallas TPU kernel: flash attention (online softmax, KV streaming).

Not a paper contribution, but the perf-critical attention kernel for the
long-sequence shapes; it follows the same fetch-once discipline: each KV tile
is streamed HBM→VMEM once per query block and reused across the whole query
tile, with O(BQ·D) accumulator state instead of the O(S²) score matrix.

Supports GQA (query head h reads KV head h // group) via the KV BlockSpec
index map, causal masking, and sliding-window masking — the gemma3-style
local attention layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bkv: int, n_kv: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (BQ, D)
    k = k_ref[0]                                    # (BKV, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    k_pos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    n_kv = skv // bkv
    grid = (b * hq, sq // bq, n_kv)

    def kv_map(h, iq, ik):
        bb = h // hq
        hh = (h % hq) // group
        return (bb * hkv + hh, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bkv=bkv, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bkv, d), kv_map),
            pl.BlockSpec((1, bkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
