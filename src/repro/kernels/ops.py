"""Jitted public entry points for the kernel layer.

Dispatch policy: on TPU the Pallas kernels run compiled; everywhere else
(this CPU container, and the 512-device dry-run which lowers through XLA)
the mathematically-identical reference path is used, keeping ``.lower()``
valid on any backend.  ``impl="pallas_interpret"`` forces the Pallas kernel
body through the interpreter — that is how the kernels are validated here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.bitmap_spmm import bitmap_spmm as _bitmap_spmm_pallas
from repro.kernels.bitmap_spmm import (
    bitmap_spmm_grouped as _bitmap_spmm_grouped_pallas)
from repro.kernels.bitmap_spmm import shard_slice
from repro.kernels.block_sparse import (
    block_sparse_matmul as _block_sparse_pallas)
from repro.kernels.flash_attention import (
    flash_attention as _flash_attention_pallas)
from repro.sparse.format import (BitmapWeight, BlockSparseWeight,
                                 unshard_bitmap)


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def bitmap_spmm(x: jax.Array, w: BitmapWeight, impl: str | None = None,
                **kw) -> jax.Array:
    """``x @ W`` with W bitmap-compressed; x may be (..., K) — leading
    dims are flattened into the kernel's row dimension (the Pallas path's
    small-M variant handles decode batches without padding to 128)."""
    impl = impl or default_impl()
    lead = x.shape[:-1]
    if x.ndim != 2:
        x = x.reshape(-1, x.shape[-1])
    if impl == "xla":
        # the reference path is tiling-independent, so the unsharded
        # fold-back is value-identical to per-shard composition
        out = _ref.bitmap_spmm_ref(x, unshard_bitmap(w))
    elif w.shard is not None:
        out = _sharded_spmm(x, w, _bitmap_spmm_pallas,
                            interpret=(impl == "pallas_interpret"), **kw)
    else:
        out = _bitmap_spmm_pallas(x, w,
                                  interpret=(impl == "pallas_interpret"),
                                  **kw)
    return out.reshape(lead + (w.shape[1],)) if len(lead) != 1 else out


def _sharded_spmm(x: jax.Array, w: BitmapWeight, kernel, **kw) -> jax.Array:
    """Per-shard Pallas dispatch over a sharded ``BitmapWeight``.

    Column shards each produce a contiguous N slice (concat); row shards
    each consume a contiguous K slice and their partial products sum —
    the same composition a psum performs across model-axis devices.
    x's contraction axis is last (2D ``(M, K)`` or grouped ``(G, M, K)``).
    """
    mode, shards = w.shard
    if mode == "col":
        return jnp.concatenate(
            [kernel(x, shard_slice(w, s), **kw) for s in range(shards)],
            axis=-1)
    ks = w.shape[0] // shards
    parts = [kernel(x[..., s * ks:(s + 1) * ks], shard_slice(w, s), **kw)
             for s in range(shards)]
    return functools.reduce(jnp.add, parts)


def bitmap_spmm_grouped(x: jax.Array, w: BitmapWeight,
                        impl: str | None = None, **kw) -> jax.Array:
    """Per-group ``x[g] @ W_g`` over a group-stacked ``BitmapWeight``
    (MoE expert stacks, RWKV lerp stacks — layout in
    ``sparse.format.pack_bitmap_experts``).  x: (G, M, K) -> (G, M, N);
    the Pallas path unrolls G small-M kernel calls so each group streams
    only its own compressed tiles."""
    impl = impl or default_impl()
    if impl == "xla":
        return _ref.bitmap_spmm_grouped_ref(x, unshard_bitmap(w))
    if w.shard is not None:
        return _sharded_spmm(x, w, _bitmap_spmm_grouped_pallas,
                             interpret=(impl == "pallas_interpret"), **kw)
    return _bitmap_spmm_grouped_pallas(
        x, w, interpret=(impl == "pallas_interpret"), **kw)


def block_sparse_matmul(x: jax.Array, w: BlockSparseWeight,
                        impl: str | None = None, **kw) -> jax.Array:
    impl = impl or default_impl()
    if impl == "xla":
        return _ref.block_sparse_matmul_ref(x, w)
    return _block_sparse_pallas(x, w, interpret=(impl == "pallas_interpret"),
                                **kw)


def flash_attention(q, k, v, impl: str | None = None, *, causal=True,
                    window=None, **kw) -> jax.Array:
    impl = impl or default_impl()
    if impl == "xla":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        interpret=(impl == "pallas_interpret"), **kw)
