"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.format import (BitmapWeight, BlockSparseWeight,
                                 unpack_bitmap, unpack_bitmap_stacked,
                                 unpack_block_sparse)


def bitmap_spmm_ref(x: jax.Array, w: BitmapWeight) -> jax.Array:
    """Oracle for ``bitmap_spmm``; also the serve-time xla dispatch.

    When the weight carries a pack-time ``dense_cache`` the EIM re-sort
    is skipped — decompression is a pack-time cost on backends without
    the Pallas kernel (see ``BitmapWeight``); without it the full
    software decompression runs, which is what the kernel parity tests
    exercise.
    """
    dense = (w.dense_cache if w.dense_cache is not None
             else unpack_bitmap(w)).astype(x.dtype)
    return jnp.dot(x, dense, preferred_element_type=jnp.float32).astype(
        x.dtype)


def bitmap_spmm_grouped_ref(x: jax.Array, w: BitmapWeight) -> jax.Array:
    """Oracle for ``bitmap_spmm_grouped``; also the serve-time xla
    dispatch for group-stacked weights (MoE expert stacks, RWKV lerp
    stacks).  x: (G, M, K); W leaves lead with G.  Returns (G, M, N).
    Like ``bitmap_spmm_ref``, a pack-time ``dense_cache`` short-circuits
    the software EIM re-sort."""
    dense = (w.dense_cache if w.dense_cache is not None
             else unpack_bitmap_stacked(w)).astype(x.dtype)
    return jnp.einsum("gmk,gkn->gmn", x, dense,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def block_sparse_matmul_ref(x: jax.Array, w: BlockSparseWeight) -> jax.Array:
    dense = unpack_block_sparse(w).astype(x.dtype)
    return jnp.dot(x, dense, preferred_element_type=jnp.float32).astype(
        x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None
                  ) -> jax.Array:
    """Dense masked attention with GQA. q: (B,Hq,S,D), k/v: (B,Hkv,S,D)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(
        q.dtype)
