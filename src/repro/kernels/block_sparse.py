"""Pallas TPU kernel: block-sparse matmul with scalar-prefetched indices.

Coarse-grain counterpart of ``bitmap_spmm``: all-zero (BK×BN) weight blocks
are *never fetched and never multiplied*.  The compressed per-column-block
K-index list (``kidx``) is the EIM idea at block granularity — matching is
done once at pack time and the grid iterates only over surviving blocks, so
no "PE" (grid step) is wasted on a failed match; ``nnzb`` masks the padded
tail steps (the only idling, bounded by load imbalance across column blocks —
the same tail the paper's Fig. 6 utilisation measures).

Uses ``PrefetchScalarGridSpec`` so the index list is resident before the
pipeline starts — the activation BlockSpec *computes its HBM address from the
prefetched index*, i.e. data-dependent fetch, exactly how SIDR's shared index
drives the SRAM address.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

from repro.sparse.format import BlockSparseWeight


def _kernel(kidx_ref, nnzb_ref, x_ref, w_ref, o_ref, acc_ref, *, smax: int):
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < nnzb_ref[j])
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0, 0],
                                preferred_element_type=jnp.float32)

    @pl.when(s == smax - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "out_dtype"))
def block_sparse_matmul(x: jax.Array, w: BlockSparseWeight, *, bm: int = 128,
                        interpret: bool = True, out_dtype=None) -> jax.Array:
    """Compute ``x @ W`` with W block-sparse.  x: (M, K) -> (M, N)."""
    m, k = x.shape
    kk, n = w.shape
    assert k == kk
    bk, bn = w.block
    nt = n // bn
    smax = w.smax
    assert m % bm == 0
    out_dtype = out_dtype or x.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // bm, nt, smax),
        in_specs=[
            # activation block chosen by the prefetched K-block index
            pl.BlockSpec((bm, bk),
                         lambda i, j, s, kidx, nnzb: (i, kidx[j, s])),
            pl.BlockSpec((1, 1, bk, bn),
                         lambda i, j, s, kidx, nnzb: (j, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda i, j, s, kidx, nnzb: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, smax=smax),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="block_sparse_matmul",
    )(w.kidx, w.nnzb, x, w.values)
