"""N:M structured sparsity (e.g. 1:4 = the paper's 75 %) — beyond-paper.

The bitmap format is maximally general but needs a per-row cumsum re-sort
at decompress time (EIM). N:M sparsity regularises *at pack time* instead:
every group of M consecutive K-elements keeps exactly N survivors, stored
as (values, 0..M-1 group offsets). Decompression is M·N selects — no
cumsum, fully vectorised, MXU-friendly — at a slightly lower compression
(1:4 ⇒ 2.67× incl. indices vs bitmap's 2.96×). This is the same
regularity-vs-generality trade the paper makes when it fixes the shared
register at 8 entries.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NmWeight:
    """(K, N) weight with N:M structure along K, tiled (BK, BN)."""

    values: jax.Array    # (KT, NT, BK//M*Nkeep, BN)
    idx: jax.Array       # (KT, NT, BK//M*Nkeep, BN) int8, offset in group
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    block: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    n_keep: int = dataclasses.field(metadata=dict(static=True))
    m_group: int = dataclasses.field(metadata=dict(static=True))

    @property
    def hbm_bytes(self) -> int:
        return (self.values.size * self.values.dtype.itemsize
                + self.idx.size)

    @property
    def compression(self) -> float:
        dense = self.shape[0] * self.shape[1] * self.values.dtype.itemsize
        return dense / self.hbm_bytes


def prune_nm(w, n: int = 1, m: int = 4) -> np.ndarray:
    """Keep the top-|n| magnitudes in every group of m along axis 0."""
    w = np.asarray(w)
    k, cols = w.shape
    assert k % m == 0
    groups = w.reshape(k // m, m, cols)
    order = np.argsort(-np.abs(groups), axis=1)
    keep = np.zeros_like(groups, dtype=bool)
    g_idx = np.arange(k // m)[:, None, None]
    c_idx = np.arange(cols)[None, None, :]
    keep[g_idx, order[:, :n, :], c_idx] = True
    return (groups * keep).reshape(k, cols)


def pack_nm(w, n: int = 1, m: int = 4,
            block: Tuple[int, int] = (128, 128)) -> NmWeight:
    """Pack an N:M-structured (K, N) array (use ``prune_nm`` first)."""
    w = np.asarray(w)
    k, cols = w.shape
    bk, bn = block
    assert k % bk == 0 and cols % bn == 0 and bk % m == 0
    kt, nt = k // bk, cols // bn

    groups = w.reshape(k // m, m, cols)
    absg = np.abs(groups)
    # positions of the n largest magnitudes, sorted by position for
    # deterministic layout
    top = np.sort(np.argsort(-absg, axis=1)[:, :n, :], axis=1)  # (K/m,n,C)
    vals = np.take_along_axis(groups, top, axis=1)               # (K/m,n,C)
    vals = vals.reshape(k // m * n, cols)
    idx = top.reshape(k // m * n, cols).astype(np.int8)

    bkc = bk // m * n
    values = vals.reshape(kt, bkc, nt, bn).transpose(0, 2, 1, 3)
    idxs = idx.reshape(kt, bkc, nt, bn).transpose(0, 2, 1, 3)
    return NmWeight(values=jnp.asarray(values), idx=jnp.asarray(idxs),
                    shape=(k, cols), block=block, n_keep=n, m_group=m)


def unpack_nm(nm: NmWeight) -> jax.Array:
    """Pure-jnp oracle: NmWeight -> dense (K, N)."""
    kt, nt, bkc, bn = nm.values.shape
    n, m = nm.n_keep, nm.m_group
    g = bkc // n                                   # groups per tile
    vals = nm.values.reshape(kt, nt, g, n, bn)
    idx = nm.idx.reshape(kt, nt, g, n, bn).astype(jnp.int32)
    pos = jnp.arange(m, dtype=jnp.int32)
    # dense[kt,nt,g,m,bn] = sum_j where(idx_j == p, val_j)
    sel = (idx[:, :, :, :, None, :] == pos[None, None, None, None, :, None])
    dense = jnp.sum(jnp.where(sel, vals[:, :, :, :, None, :], 0), axis=3)
    dense = dense.reshape(kt, nt, g * m, bn)
    return dense.transpose(0, 2, 1, 3).reshape(nm.shape)
