"""Framework-level sparse weight containers (TPU adaptation of Fig. 1).

Two storage formats, both jax pytrees so they flow through jit/pjit:

* ``BitmapWeight`` — the paper's bitmap format at VMEM-tile granularity:
  per (BK×BN) tile a packed bitmap (1 bit/element), packed non-zero values
  (row-major, padded to a per-tile budget) and per-row start offsets
  (the host-side half of EIM: the ``row_start + rank`` decompression the
  kernel performs is exactly the IMId/masked-bitmap re-sort of §II-C).
  HBM bytes ≈ density·data + 1/8·bitmap ⇒ ~3.2× traffic cut at 75 % sparsity.

* ``BlockSparseWeight`` — coarse-grain: all-zero (BK×BN) blocks are dropped
  entirely; per output-column-block a compressed list of surviving K-block
  indices (CSR-of-blocks = EIM at block granularity, consumed by the kernel
  through scalar prefetch).

Both formats enforce their structure at pack time (top-magnitude within the
budget), mirroring how the paper prunes to a target sparsity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BitmapWeight:
    """Bitmap-compressed (K, N) weight, tiled (BK, BN).

    ``dense_cache`` is an optional pack-time dense rendering consumed
    only by the *xla reference dispatch* (``ref.bitmap_spmm_ref``): on
    backends without the Pallas kernel the EIM decompression is a
    pack-time cost, not a per-step software re-sort — the hardware
    analogue decompresses in the accelerator datapath, so re-running it
    per decode step on CPU would model nothing and cost real wall time.
    It is deliberately **excluded from ``hbm_bytes``**: the traffic model
    describes the compressed stream the Pallas kernel actually fetches.
    """

    packed_bits: jax.Array   # (KT, NT, BK, BN // 8) uint8
    values: jax.Array        # (KT, NT, budget) dtype, row-major packed
    row_start: jax.Array     # (KT, NT, BK) int32 — first value slot per row
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    block: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    dense_cache: jax.Array | None = None    # (K, N) oracle-path rendering
    #: sharded layout marker: ``("col"|"row", S)`` when every array leaf
    #: carries an explicit shard axis (extent S) immediately before its
    #: tile dims — ``shard_bitmap`` below.  ``shape``/``block`` stay the
    #: full logical geometry; per-shard tiles are ``block``-sized slices
    #: of an N- (col) or K- (row) contiguous range.
    shard: Optional[Tuple[str, int]] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def budget(self) -> int:
        return self.values.shape[-1]

    @property
    def hbm_bytes(self) -> int:
        return (self.packed_bits.size * self.packed_bits.dtype.itemsize
                + self.values.size * self.values.dtype.itemsize
                + self.row_start.size * self.row_start.dtype.itemsize)

    @property
    def dense_bytes(self) -> int:
        # stacked weights (pack_bitmap_stacked: leading P axis;
        # pack_bitmap_experts: leading (P, E) axes) carry extra leading
        # dims on the arrays while `shape` stays per-matrix — count them
        stacks = math.prod(self.values.shape[:-3]) if self.values.ndim > 3 \
            else 1
        if self.shard is not None:
            # the explicit shard axis inflates the leading dims but the
            # S shards together hold exactly one logical matrix
            stacks //= self.shard[1]
        return (stacks * self.shape[0] * self.shape[1]
                * self.values.dtype.itemsize)

    @property
    def compression(self) -> float:
        return self.dense_bytes / self.hbm_bytes


def pack_bitmap(w, block: Tuple[int, int] = (128, 128),
                density_budget: float | None = None,
                budget: int | None = None,
                cache_dense: bool = False) -> BitmapWeight:
    """Pack a dense (K, N) array (zeros = pruned) into BitmapWeight.

    If a tile holds more non-zeros than ``budget = ceil(BK·BN·density_budget)``
    the smallest-magnitude surplus is re-pruned (top-k per tile), as recorded
    in DESIGN.md.  Default budget = measured max tile density.  An explicit
    ``budget`` (≥ the max tile non-zero count — packing is then lossless)
    lets callers share one value-slot budget across several packs, e.g. the
    period-stacked pack below.
    """
    w = np.asarray(w)
    k, n = w.shape
    bk, bn = block
    assert k % bk == 0 and n % bn == 0, (w.shape, block)
    assert bn % 8 == 0
    kt, nt = k // bk, n // bn
    tiles = w.reshape(kt, bk, nt, bn).transpose(0, 2, 1, 3)  # (KT,NT,BK,BN)

    bits = tiles != 0
    per_tile = bits.reshape(kt, nt, -1).sum(-1)
    if budget is not None:
        assert density_budget is None
        assert budget >= int(per_tile.max()), (budget, int(per_tile.max()))
    elif density_budget is None:
        budget = int(per_tile.max())
    else:
        budget = math.ceil(bk * bn * density_budget)
        over = per_tile > budget
        if over.any():
            flat = np.abs(tiles.reshape(kt, nt, -1))
            # keep the `budget` largest magnitudes per overflowing tile
            kth = np.partition(flat, flat.shape[-1] - budget, axis=-1)[
                ..., flat.shape[-1] - budget]
            keep = flat >= kth[..., None]
            keep &= flat > 0
            tiles = tiles * keep.reshape(tiles.shape)
            bits = tiles != 0
    budget = max(budget, 1)

    flat_bits = bits.reshape(kt, nt, bk, bn)
    row_nnz = flat_bits.sum(-1)
    row_start = np.zeros((kt, nt, bk), np.int32)
    row_start[:, :, 1:] = np.cumsum(row_nnz, -1)[:, :, :-1]

    ranks = np.cumsum(flat_bits, -1) - 1
    slot = row_start[..., None] + ranks
    values = np.zeros((kt, nt, budget), w.dtype)
    i0, i1, i2, i3 = np.nonzero(flat_bits)
    values[i0, i1, slot[i0, i1, i2, i3]] = tiles[i0, i1, i2, i3]

    packed = np.packbits(flat_bits, axis=-1, bitorder="little")
    dense = (jnp.asarray(tiles.transpose(0, 2, 1, 3).reshape(k, n))
             if cache_dense else None)
    return BitmapWeight(
        packed_bits=jnp.asarray(packed),
        values=jnp.asarray(values),
        row_start=jnp.asarray(row_start),
        shape=(k, n), block=(bk, bn), dense_cache=dense)


def unpack_bitmap(bw: BitmapWeight) -> jax.Array:
    """Pure-jnp decompression oracle (mirrors the in-kernel EIM re-sort)."""
    kt, nt, bk, bnb = bw.packed_bits.shape
    bn = bnb * 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bw.packed_bits[..., None] >> shifts) & 1        # (KT,NT,BK,BN/8,8)
    bits = bits.reshape(kt, nt, bk, bn).astype(jnp.int32)
    rank = jnp.cumsum(bits, -1) - 1
    idx = jnp.clip(bw.row_start[..., None] + rank, 0, bw.budget - 1)
    vals = jnp.take_along_axis(
        bw.values[:, :, None, :], idx.reshape(kt, nt, bk * bn)[:, :, None, :],
        axis=-1).reshape(kt, nt, bk, bn)
    dense_tiles = jnp.where(bits != 0, vals, 0)
    return dense_tiles.transpose(0, 2, 1, 3).reshape(bw.shape)


def pack_bitmap_stacked(w, block: Tuple[int, int],
                        cache_dense: bool = False) -> BitmapWeight:
    """Pack a period-stacked (P, K, N) tensor into one ``BitmapWeight``
    whose array leaves carry a leading P axis.

    All periods share the tile ``block`` and one value-slot ``budget``
    (the max tile non-zero count across periods), so ``lax.scan`` over the
    stacked container yields a plain per-period ``BitmapWeight`` each
    iteration — exactly how the serving decode step consumes it.  Packing
    is lossless: no re-pruning happens at pack time.
    """
    w = np.asarray(w)
    assert w.ndim == 3, w.shape
    p, k, n = w.shape
    bk, bn = block
    assert k % bk == 0 and n % bn == 0, (w.shape, block)
    kt, nt = k // bk, n // bn
    tile_nnz = (w.reshape(p, kt, bk, nt, bn) != 0).transpose(
        0, 1, 3, 2, 4).reshape(p, kt, nt, -1).sum(-1)
    budget = max(1, int(tile_nnz.max()))
    per = [pack_bitmap(w[i], block=block, budget=budget,
                       cache_dense=cache_dense) for i in range(p)]
    return BitmapWeight(
        packed_bits=jnp.stack([q.packed_bits for q in per]),
        values=jnp.stack([q.values for q in per]),
        row_start=jnp.stack([q.row_start for q in per]),
        shape=(k, n), block=block,
        dense_cache=(jnp.stack([q.dense_cache for q in per])
                     if cache_dense else None))


def unpack_bitmap_stacked(bw: BitmapWeight) -> jax.Array:
    """Dense oracle for a stacked ``BitmapWeight``: recurses over every
    leading stack axis (one for period-stacked tensors, two for the
    (P, E) expert layout), returning ``(*stack_axes, K, N)``."""
    if bw.values.ndim == 3:
        return unpack_bitmap(bw)
    return jnp.stack([
        unpack_bitmap_stacked(BitmapWeight(
            packed_bits=bw.packed_bits[i], values=bw.values[i],
            row_start=bw.row_start[i], shape=bw.shape, block=bw.block))
        for i in range(bw.packed_bits.shape[0])])


def pack_bitmap_experts(w, block: Tuple[int, int],
                        cache_dense: bool = False) -> BitmapWeight:
    """Pack a period-stacked expert stack (P, E, K, N) into one
    ``BitmapWeight`` whose array leaves carry leading (P, E) axes.

    The expert analogue of ``pack_bitmap_stacked``: every (period,
    expert) matrix shares the tile ``block`` and one value-slot
    ``budget`` (the max tile non-zero count across the whole stack), so
    the serve-time ``lax.scan`` over periods yields an (E, ...)-leading
    ``BitmapWeight`` each iteration whose per-expert slices the grouped
    kernel dispatch (``kernels/ops.bitmap_spmm_grouped``) consumes.
    Packing is lossless — no re-pruning happens at pack time.

    Also used for non-router group stacks with the same dataflow (e.g.
    RWKV6's 5-way low-rank lerp stack ``mix_B``): any (P, G, K, N)
    tensor consumed as G independent (K, N) GEMMs packs this way.
    """
    w = np.asarray(w)
    assert w.ndim == 4, w.shape
    p, e, k, n = w.shape
    flat = pack_bitmap_stacked(w.reshape(p * e, k, n), block=block,
                               cache_dense=cache_dense)
    return BitmapWeight(
        packed_bits=flat.packed_bits.reshape(
            (p, e) + flat.packed_bits.shape[1:]),
        values=flat.values.reshape((p, e) + flat.values.shape[1:]),
        row_start=flat.row_start.reshape((p, e) + flat.row_start.shape[1:]),
        shape=(k, n), block=block,
        dense_cache=(flat.dense_cache.reshape(p, e, k, n)
                     if cache_dense else None))


def unpack_bitmap_experts(bw: BitmapWeight) -> jax.Array:
    """Dense (P, E, K, N) oracle for an expert-stacked ``BitmapWeight``."""
    return unpack_bitmap_stacked(bw)


# --------------------------------------------------------------------------
# Sharded layout: EIE-style partitioning of the compressed stream.  The N
# (column-parallel) or K (row-parallel) tile axis is split into S
# contiguous shard ranges and re-exposed as an explicit shard axis placed
# immediately before each leaf's tile dims, so a single PartitionSpec axis
# ('model' at that position) makes every shard's bitmap+values+row_start
# device-local.  ``shape``/``block`` keep the full logical geometry.

#: trailing per-tile dims of each array leaf (the shard axis sits just
#: before these; leading stack axes — period P, expert E — come first)
_TILE_ND = {"packed_bits": 4, "values": 3, "row_start": 3, "dense_cache": 2}

#: offset from ndim of the tile axis being split: col splits the NT axis
#: (second tile dim — or N itself for dense_cache), row splits KT/K
_SHARD_OFF = {"col": lambda tile_nd: tile_nd - 1, "row": lambda tile_nd: tile_nd}


def _split_leaf(leaf, tile_nd: int, mode: str, shards: int):
    """Split the sharded tile axis into ``shards`` contiguous ranges and
    move the new shard axis to just before the tile dims."""
    if leaf is None:
        return None
    nd = leaf.ndim
    ax = nd - _SHARD_OFF[mode](tile_nd)
    size = leaf.shape[ax]
    assert size % shards == 0, (leaf.shape, ax, shards)
    r = leaf.reshape(leaf.shape[:ax] + (shards, size // shards)
                     + leaf.shape[ax + 1:])
    return jnp.moveaxis(r, ax, nd - tile_nd)


def _merge_leaf(leaf, tile_nd: int, mode: str):
    """Inverse of ``_split_leaf``: fold the shard axis back into the tile
    axis it was split from (shard ranges are contiguous, so this is a
    pure reshape after the moveaxis)."""
    if leaf is None:
        return None
    n = leaf.ndim
    j = n - _SHARD_OFF[mode](tile_nd) - 1
    m = jnp.moveaxis(leaf, n - tile_nd - 1, j)
    return m.reshape(m.shape[:j] + (m.shape[j] * m.shape[j + 1],)
                     + m.shape[j + 2:])


def shard_bitmap(bw: BitmapWeight, shards: int, mode: str) -> BitmapWeight:
    """Re-layout a packed ``BitmapWeight`` with an explicit shard axis.

    ``mode="col"`` splits the output-column tile axis (NT) — each shard
    owns a contiguous N range (wq/wk/wv/w_gate/w_up, vocab-split head);
    ``mode="row"`` splits the contraction tile axis (KT) — each shard
    owns a K range and partial products sum (wo/w_down).  Lossless: the
    per-shard leaves are exact slices of the unsharded pack.
    """
    assert mode in ("col", "row"), mode
    assert bw.shard is None, bw.shard
    if shards == 1:
        return bw
    return dataclasses.replace(
        bw,
        packed_bits=_split_leaf(bw.packed_bits, 4, mode, shards),
        values=_split_leaf(bw.values, 3, mode, shards),
        row_start=_split_leaf(bw.row_start, 3, mode, shards),
        dense_cache=_split_leaf(bw.dense_cache, 2, mode, shards),
        shard=(mode, shards))


def unshard_bitmap(bw: BitmapWeight) -> BitmapWeight:
    """Fold the explicit shard axis back in — the exact unsharded pack."""
    if bw.shard is None:
        return bw
    mode, _ = bw.shard
    return dataclasses.replace(
        bw,
        packed_bits=_merge_leaf(bw.packed_bits, 4, mode),
        values=_merge_leaf(bw.values, 3, mode),
        row_start=_merge_leaf(bw.row_start, 3, mode),
        dense_cache=_merge_leaf(bw.dense_cache, 2, mode),
        shard=None)


def gather_bitmap(bw: BitmapWeight, axis_name: str) -> BitmapWeight:
    """Inside ``shard_map``: all-gather each device's shard slice over
    ``axis_name`` and fold the shard axis away, yielding the full
    unsharded ``BitmapWeight`` (value-identical to the single-device
    pack, so downstream compute needs no per-shard composition)."""
    if bw.shard is None:
        return bw
    mode, _ = bw.shard

    def g(leaf, tile_nd):
        if leaf is None:
            return None
        ax = leaf.ndim - tile_nd - 1
        return _merge_leaf(
            jax.lax.all_gather(leaf, axis_name, axis=ax, tiled=True),
            tile_nd, mode)

    return dataclasses.replace(
        bw,
        packed_bits=g(bw.packed_bits, 4),
        values=g(bw.values, 3),
        row_start=g(bw.row_start, 3),
        dense_cache=g(bw.dense_cache, 2),
        shard=None)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BlockSparseWeight:
    """Block-sparse (K, N) weight: zero (BK×BN) blocks dropped."""

    values: jax.Array     # (NT, SMAX, BK, BN) surviving blocks per col-block
    kidx: jax.Array       # (NT, SMAX) int32 — source K-block index (pad: 0)
    nnzb: jax.Array       # (NT,) int32 — number of valid blocks per col-block
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    block: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def smax(self) -> int:
        return self.values.shape[1]

    @property
    def hbm_bytes(self) -> int:
        return (self.values.size * self.values.dtype.itemsize
                + self.kidx.size * 4 + self.nnzb.size * 4)

    @property
    def density(self) -> float:
        kt = self.shape[0] // self.block[0]
        return float(np.asarray(self.nnzb).sum()) / (kt * self.kidx.shape[0])


def pack_block_sparse(w, block: Tuple[int, int] = (128, 128)
                      ) -> BlockSparseWeight:
    w = np.asarray(w)
    k, n = w.shape
    bk, bn = block
    assert k % bk == 0 and n % bn == 0
    kt, nt = k // bk, n // bn
    tiles = w.reshape(kt, bk, nt, bn).transpose(2, 0, 1, 3)  # (NT,KT,BK,BN)
    alive = (tiles != 0).any((-1, -2))                        # (NT, KT)
    nnzb = alive.sum(-1).astype(np.int32)
    smax = max(int(nnzb.max()), 1)
    values = np.zeros((nt, smax, bk, bn), w.dtype)
    kidx = np.zeros((nt, smax), np.int32)
    for j in range(nt):
        ks = np.nonzero(alive[j])[0]
        values[j, :len(ks)] = tiles[j, ks]
        kidx[j, :len(ks)] = ks
    return BlockSparseWeight(
        values=jnp.asarray(values), kidx=jnp.asarray(kidx),
        nnzb=jnp.asarray(nnzb), shape=(k, n), block=(bk, bn))


def unpack_block_sparse(bw: BlockSparseWeight) -> jax.Array:
    nt, smax, bk, bn = bw.values.shape
    kt = bw.shape[0] // bk
    dense = jnp.zeros((nt, kt, bk, bn), bw.values.dtype)
    valid = jnp.arange(smax)[None, :] < bw.nnzb[:, None]
    vals = jnp.where(valid[..., None, None], bw.values, 0)
    j = jnp.repeat(jnp.arange(nt), smax)
    dense = dense.at[j, bw.kidx.reshape(-1)].add(
        vals.reshape(nt * smax, bk, bn))
    return dense.transpose(1, 2, 0, 3).reshape(bw.shape)
