"""Framework-level sparse weight containers (TPU adaptation of Fig. 1).

Two storage formats, both jax pytrees so they flow through jit/pjit:

* ``BitmapWeight`` — the paper's bitmap format at VMEM-tile granularity:
  per (BK×BN) tile a packed bitmap (1 bit/element), packed non-zero values
  (row-major, padded to a per-tile budget) and per-row start offsets
  (the host-side half of EIM: the ``row_start + rank`` decompression the
  kernel performs is exactly the IMId/masked-bitmap re-sort of §II-C).
  HBM bytes ≈ density·data + 1/8·bitmap ⇒ ~3.2× traffic cut at 75 % sparsity.

* ``BlockSparseWeight`` — coarse-grain: all-zero (BK×BN) blocks are dropped
  entirely; per output-column-block a compressed list of surviving K-block
  indices (CSR-of-blocks = EIM at block granularity, consumed by the kernel
  through scalar prefetch).

Both formats enforce their structure at pack time (top-magnitude within the
budget), mirroring how the paper prunes to a target sparsity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BitmapWeight:
    """Bitmap-compressed (K, N) weight, tiled (BK, BN)."""

    packed_bits: jax.Array   # (KT, NT, BK, BN // 8) uint8
    values: jax.Array        # (KT, NT, budget) dtype, row-major packed
    row_start: jax.Array     # (KT, NT, BK) int32 — first value slot per row
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    block: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def budget(self) -> int:
        return self.values.shape[-1]

    @property
    def hbm_bytes(self) -> int:
        return (self.packed_bits.size * self.packed_bits.dtype.itemsize
                + self.values.size * self.values.dtype.itemsize
                + self.row_start.size * self.row_start.dtype.itemsize)

    @property
    def dense_bytes(self) -> int:
        return self.shape[0] * self.shape[1] * self.values.dtype.itemsize

    @property
    def compression(self) -> float:
        return self.dense_bytes / self.hbm_bytes


def pack_bitmap(w, block: Tuple[int, int] = (128, 128),
                density_budget: float | None = None) -> BitmapWeight:
    """Pack a dense (K, N) array (zeros = pruned) into BitmapWeight.

    If a tile holds more non-zeros than ``budget = ceil(BK·BN·density_budget)``
    the smallest-magnitude surplus is re-pruned (top-k per tile), as recorded
    in DESIGN.md.  Default budget = measured max tile density.
    """
    w = np.asarray(w)
    k, n = w.shape
    bk, bn = block
    assert k % bk == 0 and n % bn == 0, (w.shape, block)
    assert bn % 8 == 0
    kt, nt = k // bk, n // bn
    tiles = w.reshape(kt, bk, nt, bn).transpose(0, 2, 1, 3)  # (KT,NT,BK,BN)

    bits = tiles != 0
    per_tile = bits.reshape(kt, nt, -1).sum(-1)
    if density_budget is None:
        budget = int(per_tile.max())
    else:
        budget = math.ceil(bk * bn * density_budget)
        over = per_tile > budget
        if over.any():
            flat = np.abs(tiles.reshape(kt, nt, -1))
            # keep the `budget` largest magnitudes per overflowing tile
            kth = np.partition(flat, flat.shape[-1] - budget, axis=-1)[
                ..., flat.shape[-1] - budget]
            keep = flat >= kth[..., None]
            keep &= flat > 0
            tiles = tiles * keep.reshape(tiles.shape)
            bits = tiles != 0
    budget = max(budget, 1)

    flat_bits = bits.reshape(kt, nt, bk, bn)
    row_nnz = flat_bits.sum(-1)
    row_start = np.zeros((kt, nt, bk), np.int32)
    row_start[:, :, 1:] = np.cumsum(row_nnz, -1)[:, :, :-1]

    ranks = np.cumsum(flat_bits, -1) - 1
    slot = row_start[..., None] + ranks
    values = np.zeros((kt, nt, budget), w.dtype)
    i0, i1, i2, i3 = np.nonzero(flat_bits)
    values[i0, i1, slot[i0, i1, i2, i3]] = tiles[i0, i1, i2, i3]

    packed = np.packbits(flat_bits, axis=-1, bitorder="little")
    return BitmapWeight(
        packed_bits=jnp.asarray(packed),
        values=jnp.asarray(values),
        row_start=jnp.asarray(row_start),
        shape=(k, n), block=(bk, bn))


def unpack_bitmap(bw: BitmapWeight) -> jax.Array:
    """Pure-jnp decompression oracle (mirrors the in-kernel EIM re-sort)."""
    kt, nt, bk, bnb = bw.packed_bits.shape
    bn = bnb * 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bw.packed_bits[..., None] >> shifts) & 1        # (KT,NT,BK,BN/8,8)
    bits = bits.reshape(kt, nt, bk, bn).astype(jnp.int32)
    rank = jnp.cumsum(bits, -1) - 1
    idx = jnp.clip(bw.row_start[..., None] + rank, 0, bw.budget - 1)
    vals = jnp.take_along_axis(
        bw.values[:, :, None, :], idx.reshape(kt, nt, bk * bn)[:, :, None, :],
        axis=-1).reshape(kt, nt, bk, bn)
    dense_tiles = jnp.where(bits != 0, vals, 0)
    return dense_tiles.transpose(0, 2, 1, 3).reshape(bw.shape)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BlockSparseWeight:
    """Block-sparse (K, N) weight: zero (BK×BN) blocks dropped."""

    values: jax.Array     # (NT, SMAX, BK, BN) surviving blocks per col-block
    kidx: jax.Array       # (NT, SMAX) int32 — source K-block index (pad: 0)
    nnzb: jax.Array       # (NT,) int32 — number of valid blocks per col-block
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    block: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def smax(self) -> int:
        return self.values.shape[1]

    @property
    def hbm_bytes(self) -> int:
        return (self.values.size * self.values.dtype.itemsize
                + self.kidx.size * 4 + self.nnzb.size * 4)

    @property
    def density(self) -> float:
        kt = self.shape[0] // self.block[0]
        return float(np.asarray(self.nnzb).sum()) / (kt * self.kidx.shape[0])


def pack_block_sparse(w, block: Tuple[int, int] = (128, 128)
                      ) -> BlockSparseWeight:
    w = np.asarray(w)
    k, n = w.shape
    bk, bn = block
    assert k % bk == 0 and n % bn == 0
    kt, nt = k // bk, n // bn
    tiles = w.reshape(kt, bk, nt, bn).transpose(2, 0, 1, 3)  # (NT,KT,BK,BN)
    alive = (tiles != 0).any((-1, -2))                        # (NT, KT)
    nnzb = alive.sum(-1).astype(np.int32)
    smax = max(int(nnzb.max()), 1)
    values = np.zeros((nt, smax, bk, bn), w.dtype)
    kidx = np.zeros((nt, smax), np.int32)
    for j in range(nt):
        ks = np.nonzero(alive[j])[0]
        values[j, :len(ks)] = tiles[j, ks]
        kidx[j, :len(ks)] = ks
    return BlockSparseWeight(
        values=jnp.asarray(values), kidx=jnp.asarray(kidx),
        nnzb=jnp.asarray(nnzb), shape=(k, n), block=(bk, bn))


def unpack_block_sparse(bw: BlockSparseWeight) -> jax.Array:
    nt, smax, bk, bn = bw.values.shape
    kt = bw.shape[0] // bk
    dense = jnp.zeros((nt, kt, bk, bn), bw.values.dtype)
    valid = jnp.arange(smax)[None, :] < bw.nnzb[:, None]
    vals = jnp.where(valid[..., None, None], bw.values, 0)
    j = jnp.repeat(jnp.arange(nt), smax)
    dense = dense.at[j, bw.kidx.reshape(-1)].add(
        vals.reshape(nt * smax, bk, bn))
    return dense.transpose(1, 2, 0, 3).reshape(bw.shape)
