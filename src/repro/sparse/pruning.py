"""Global L1 fine-grained pruning (Han et al. [1]) on jax pytrees.

The paper prunes MobileNetV2 to 75 % weight sparsity with a single global
magnitude threshold; this module does the same for framework models, plus a
per-tensor variant and sparsity accounting helpers.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def _is_prunable(path: tuple, leaf: jax.Array,
                 predicate: Callable | None) -> bool:
    if leaf.ndim < 2:      # biases, norms, scalars stay dense
        return False
    if predicate is not None:
        return predicate(path, leaf)
    name = "/".join(str(p) for p in path).lower()
    return "embed" not in name  # embeddings stay dense by default


def global_l1_prune(params: Any, sparsity: float,
                    predicate: Callable | None = None) -> Any:
    """Zero the globally-smallest |w| fraction across all prunable leaves."""
    if sparsity <= 0:
        return params
    leaves = jax.tree_util.tree_leaves_with_path(params)
    prunable = [(p, l) for p, l in leaves if _is_prunable(p, l, predicate)]
    if not prunable:
        return params
    mags = jnp.concatenate([jnp.abs(l).reshape(-1) for _, l in prunable])
    thresh = jnp.quantile(mags.astype(jnp.float32), sparsity)

    flat_paths = {jax.tree_util.keystr(p) for p, _ in prunable}

    def prune_leaf(path, leaf):
        if jax.tree_util.keystr(path) in flat_paths:
            return jnp.where(jnp.abs(leaf) <= thresh, 0, leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(prune_leaf, params)


def per_tensor_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Magnitude-prune a single tensor to exactly ``sparsity``."""
    if sparsity <= 0:
        return w
    k = int(round(sparsity * w.size))
    if k <= 0:
        return w
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[k - 1]
    return jnp.where(jnp.abs(w) <= thresh, 0, w)


def sparsity_of(params: Any) -> float:
    leaves = [l for l in jax.tree_util.tree_leaves(params)
              if hasattr(l, "size") and l.ndim >= 2]
    total = sum(l.size for l in leaves)
    zeros = sum(int((l == 0).sum()) for l in leaves)
    return zeros / max(total, 1)
