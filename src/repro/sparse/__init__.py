"""Framework-level sparsity: formats, pruning, sparse linear ops."""
from repro.sparse.format import (BitmapWeight, BlockSparseWeight, pack_bitmap,
                                 pack_bitmap_experts, pack_bitmap_stacked,
                                 pack_block_sparse, unpack_bitmap,
                                 unpack_bitmap_experts, unpack_bitmap_stacked,
                                 unpack_block_sparse)
from repro.sparse.pruning import (global_l1_prune, per_tensor_prune,
                                  sparsity_of)

__all__ = [
    "BitmapWeight", "BlockSparseWeight", "pack_bitmap",
    "pack_bitmap_experts", "pack_bitmap_stacked", "pack_block_sparse",
    "unpack_bitmap", "unpack_bitmap_experts", "unpack_bitmap_stacked",
    "unpack_block_sparse", "global_l1_prune", "per_tensor_prune",
    "sparsity_of",
]
