"""Training substrate: optimizer, checkpointing, gradient compression."""
from repro.train.optimizer import OptConfig

__all__ = ["OptConfig"]
