"""Gradient compression for the DP axis: int8 quantisation + error feedback.

At 1000+-node scale the data-parallel gradient all-reduce dominates the
inter-pod links; per-tensor-scaled int8 cuts it 4× vs f32 (2× vs bf16).
Error feedback (Karimireddy et al.) accumulates the quantisation residual
locally and re-adds it next step, preserving convergence.

Implemented as a ``shard_map`` wrapper around a per-shard gradient
function: inside the map, local gradients are quantised, ``psum``-ed as
int32 (wire = int8 payload semantics; XLA all-reduces the small dtype),
and dequantised.  Used by ``examples/``-scale runs and tested for
convergence parity in tests/test_compression.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_nocheck


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any) -> Tuple[Any, Any, Any]:
    """Quantise a gradient pytree; returns (q_tree, scales, residuals)."""
    qs, scales, residuals = [], [], []
    leaves, treedef = jax.tree.flatten(grads)
    for g in leaves:
        q, s = quantize_int8(g.astype(jnp.float32))
        qs.append(q)
        scales.append(s)
        residuals.append(g.astype(jnp.float32) - dequantize_int8(q, s))
    unf = functools.partial(jax.tree.unflatten, treedef)
    return unf(qs), unf(scales), unf(residuals)


def decompress_tree(q_tree: Any, scales: Any) -> Any:
    return jax.tree.map(dequantize_int8, q_tree, scales)


def compressed_psum_grads(grad_fn: Callable, mesh, axis: str = "data"
                          ) -> Callable:
    """Wrap ``grad_fn(params, batch) -> grads`` into a shard_map that
    int8-compresses the per-shard gradients before the DP all-reduce.

    Returns ``fn(params, batch, error_fb) -> (grads, new_error_fb)``;
    ``error_fb`` is the per-shard error-feedback pytree with a leading
    shard dim (``init_error_fb``).  Params replicated across ``axis``;
    batch sharded on it.
    """

    def local(params, batch, err):
        g = grad_fn(params, batch)
        g = jax.tree.map(lambda a, e: a.astype(jnp.float32) + e[0], g, err)
        q, scales, resid = compress_tree(g)
        # wire payload: int8 values (+ scalar scales)
        summed = jax.tree.map(
            lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis), q)
        n = jax.lax.psum(1, axis)
        scale_sum = jax.tree.map(lambda s: jax.lax.psum(s, axis) / n, scales)
        grads = jax.tree.map(
            lambda sm, sc: sm.astype(jnp.float32) * sc / n,
            summed, scale_sum)
        return grads, jax.tree.map(lambda r: r[None], resid)

    def wrapped(params, batch, err):
        return shard_map_nocheck(
            local, mesh=mesh,
            in_specs=(P(), P(axis), P(axis)),
            out_specs=(P(), P(axis)),
        )(params, batch, err)

    return wrapped


def init_error_fb(grads_like: Any, n_shards: int) -> Any:
    """Per-shard error-feedback state (leading shard dim)."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_shards,) + tuple(g.shape), jnp.float32),
        grads_like)
