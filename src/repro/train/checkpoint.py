"""Fault-tolerant checkpointing (no orbax): atomic, sharded, resumable.

Layout:  <dir>/step_<n>/
            meta.json              — step, tree structure, leaf manifest
            leaf_<i>.npy           — one array per pytree leaf
            _COMPLETE              — commit marker (written last)

Writes go to ``step_<n>.tmp`` and are renamed only after the commit marker
is in place, so a crash mid-write never corrupts the latest checkpoint;
``latest_step`` ignores uncommitted directories.  ``restore`` re-shards
leaves onto whatever mesh the caller provides (elastic restarts: the DP
extent may have changed).  Retries wrap all filesystem ops (flaky NFS on
big clusters).  An optional background thread gives async write-behind.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


def _retry(fn: Callable, attempts: int = 3, delay: float = 0.5):
    for i in range(attempts):
        try:
            return fn()
        except OSError:
            if i == attempts - 1:
                raise
            time.sleep(delay * (2 ** i))


def save(ckpt_dir: str, step: int, tree: Any,
         keep: int = 3, async_: bool = False) -> Optional[threading.Thread]:
    """Checkpoint a pytree. With ``async_`` the device->host copy happens
    synchronously (tiny) and the file write happens on a daemon thread."""
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        _retry(lambda: os.makedirs(tmp, exist_ok=True))
        for i, arr in enumerate(host_leaves):
            _retry(lambda a=arr, j=i: np.save(
                os.path.join(tmp, f"leaf_{j}.npy"), a))
        meta = {
            "step": step,
            "num_leaves": len(host_leaves),
            "treedef": str(treedef),
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        _retry(lambda: os.rename(tmp, final))
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(completed_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def completed_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "_COMPLETE")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = completed_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs), placing leaves with ``shardings`` if given —
    this is the elastic-restart path: the saved full arrays are laid out
    onto the *current* mesh regardless of the mesh they were saved from."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    assert os.path.exists(os.path.join(path, "_COMPLETE")), \
        f"checkpoint {path} is not committed"
    leaves, treedef = jax.tree.flatten(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = _retry(lambda j=i: np.load(os.path.join(path, f"leaf_{j}.npy")))
        assert tuple(arr.shape) == tuple(ref.shape), (
            f"leaf {i}: {arr.shape} vs {ref.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, out)
