"""AdamW + global-norm clipping + cosine schedule, from scratch (no optax).

Pure pytree functions; optimizer state shards exactly like the parameters,
so the same PartitionSpecs apply (see launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``min_lr_ratio``·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (not norms/biases/ssm scalars)."""
    name = jax.tree_util.keystr(path).lower()
    return not any(k in name for k in
                   ("norm", "bias", "a_log", "mu", "['u']", "w0", "gn_scale",
                    "conv_b", "['d']"))


def update(params: Any, grads: Any, state: Dict, cfg: OptConfig
           ) -> Tuple[Any, Dict, Dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_g, tdef = jax.tree_util.tree_flatten_with_path(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])

    new_p, new_m, new_v = [], [], []
    for (path, g), p, m, v in zip(flat_g, flat_p, flat_m, flat_v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unflatten = jax.tree_util.tree_unflatten
    treedef = jax.tree.structure(params)
    return (unflatten(treedef, new_p),
            {"m": unflatten(treedef, new_m),
             "v": unflatten(treedef, new_v), "step": step},
            {"grad_norm": gnorm, "lr": lr})
