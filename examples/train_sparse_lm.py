"""End-to-end driver: train an LM with the paper's sparsity regime
(global-L1 prune + masked sparse training), fault-tolerant checkpointing
included.

Two presets: the default ``--size 20m`` finishes a few hundred steps on
this CPU container; ``--size 100m`` is the full ~100M-param run (same code
path, sized for real devices).  Data is the deterministic synthetic stream
(repro/data); expect loss to drop from ~ln(V) toward the copy-structure
floor.

Run:  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import train
from repro.models.config import BlockCfg, ModelConfig


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="olmo-100m",
        d_model=512, num_layers=8, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=32_768,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln_nonparam", act="silu", max_seq_len=512,
    )


def model_20m() -> ModelConfig:
    return ModelConfig(
        name="olmo-100m",  # same registry id, CPU-sized
        d_model=256, num_layers=4, num_heads=4, num_kv_heads=4,
        d_ff=1024, vocab_size=8192,
        pattern=(BlockCfg(mixer="attn"),),
        norm="ln_nonparam", act="silu", max_seq_len=512,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--size", choices=("20m", "100m"), default="20m")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_sparse_lm")
    args = ap.parse_args()

    import repro.launch.train as T
    import repro.configs as C
    model = model_100m() if args.size == "100m" else model_20m()
    # register the custom config through the smoke hook
    orig = C.get_smoke_config
    C.get_smoke_config = lambda a: (model if a == "olmo-100m" else orig(a))
    T.get_smoke_config = C.get_smoke_config
    n = model.param_count()
    print(f"training olmo-100m ({n/1e6:.1f}M params) at "
          f"{args.sparsity:.0%} weight sparsity")
    res = train("olmo-100m", smoke=True, steps=args.steps, batch=args.batch,
                seq=args.seq, sparsity=args.sparsity, lr=1e-3,
                ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10)
    first, last = res["losses"][0], res["final_loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "training failed to reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
