"""Batched sparse serving example: decode with a pruned hybrid model.

Serves the jamba-style hybrid (attention + Mamba + MoE) smoke model with
batched greedy decode and 50 % pruned weights — the state-based layers are
what make long-context serving tractable (see the long_500k dry-run cells).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import serve


def main():
    res = serve("jamba-v0.1-52b", smoke=True, batch=4, steps=24,
                max_len=64, sparsity=0.5)
    assert res["tokens"].shape == (4, 24)
    print("decoded token matrix (first 2 rows):")
    print(res["tokens"][:2])
    print("OK")


if __name__ == "__main__":
    main()
