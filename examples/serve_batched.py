"""Continuous-batching sparse serving example: a stream of requests into
the jamba-style hybrid (attention + Mamba + MoE) smoke model.

Six requests arrive over time into a 2-slot engine with 50 % pruned
weights: the scheduler admits each into the first freed slot (no drain
barrier), the slotted KV cache is zeroed and reused per admission, and
every packable projection (attention q/k/v/o here; Mamba/MoE tensors
record dense fallbacks) plus the LM head streams in the paper's
bitmap-compressed format every step.

The KV cache is paged (``paged=True``): attention blocks cache into
fixed-size pages gathered through per-slot page tables, so reserved
cache bytes track live tokens instead of ``num_slots × max_len``
(Mamba state stays slotted — it is O(1) per slot).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.serve import ServeEngine, poisson_trace


def main():
    eng = ServeEngine.from_arch("jamba-v0.1-52b", smoke=True, num_slots=2,
                                max_len=64, sparsity=0.5, seed=0,
                                paged=True, page_len=8)
    trace = poisson_trace(6, rate=0.4, seed=0,
                          vocab_size=eng.cfg.vocab_size, max_new=(8, 16))
    reqs = [eng.submit(**spec) for spec in trace]
    rep = eng.run()

    assert rep["requests"] == 6
    assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
    slots_used = {r.slot for r in reqs}
    print(f"decoded {rep['generated_tokens']} tokens across "
          f"{rep['requests']} requests on {len(slots_used)} slots "
          f"({rep['tok_per_s']:.1f} tok/s, occupancy "
          f"{rep['slot_occupancy']:.0%})")
    lat = rep["latency_s"]
    print(f"latency p50 {lat['p50'] * 1e3:.1f}ms / p99 "
          f"{lat['p99'] * 1e3:.1f}ms; per-request slots: "
          f"{[r.slot for r in reqs]}")
    pg = rep["paging"]
    print(f"paged KV: peak {pg['pages_peak']} of {pg['pages_total']} "
          f"pool pages; reserved {pg['reserved_kv_bytes']/1e3:.1f}kB vs "
          f"contiguous {pg['contiguous_kv_bytes']/1e3:.1f}kB")
    print("OK")


if __name__ == "__main__":
    main()
