"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

1. Build a sparse GEMM workload (75 % global-L1 pruned weights, as the
   paper prunes MobileNetV2).
2. Run it through the cycle-accurate EIM+SIDR accelerator model — get the
   paper's metrics (MAPM, utilisation, speed-up, TOPS/W) and verify the
   output against a dense matmul.
3. Pack the same weights into the TPU bitmap format and run the Pallas
   ``bitmap_spmm`` kernel (interpret mode on CPU) against its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.kernels import ops, ref
from repro.sparse import pack_bitmap

rng = np.random.default_rng(0)

# -- 1. sparse workload ------------------------------------------------------
x = random_sparse((128, 256), sparsity=0.45, rng=rng)          # activations
w = prune_global_l1(rng.standard_normal((128, 256)).astype(np.float32),
                    sparsity=0.75)                              # weights

# -- 2. the paper's accelerator ---------------------------------------------
report = run_gemm(x, w, compute_values=True)
np.testing.assert_allclose(report.outputs, x @ w.T, atol=1e-4)
print("accelerator (16x16 PE array, EIM + SIDR):")
for k, v in report.summary().items():
    print(f"  {k:28s} {v}")

# -- 3. the TPU adaptation ---------------------------------------------------
wt = w.T.copy()                                                 # (K=256, N=128)
bw = pack_bitmap(wt, block=(128, 128))
xj = jnp.asarray(x, jnp.float32)
out = ops.bitmap_spmm(xj, bw, impl="pallas_interpret")
expect = ref.bitmap_spmm_ref(xj, bw)
err = float(jnp.abs(out - expect).max())
print(f"\nbitmap_spmm kernel: weight HBM compression "
      f"{bw.compression:.2f}x, max |err| vs oracle {err:.2e}")
assert err < 1e-3
print("OK")
