"""Design-space study of the paper's accelerator (beyond-paper ablations).

Sweeps the two structural knobs the paper fixes — shared-register size
(8) and PE-array shape (16×16) — over the MobileNetV2-like operating point
and reports MAPM / utilisation / TOPS/W for each, answering "did the paper
pick a good design point?" (Spoiler: reg=8 sits at the knee.)

Run:  PYTHONPATH=src python examples/accelerator_study.py
"""
import numpy as np

from repro.core.accelerator import AcceleratorConfig, run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.core.energy import energy_from_stats, tops_per_watt


def main():
    rng = np.random.default_rng(0)
    x = random_sparse((256, 512), 0.45, rng)
    w = prune_global_l1(rng.standard_normal((256, 512)).astype(np.float32),
                        0.75)

    print("shared-register size sweep (PE array fixed 16x16):")
    for reg in (2, 4, 8, 16, 32):
        rep = run_gemm(x, w, AcceleratorConfig(reg_size=reg))
        e = energy_from_stats(rep.stats)
        print(f"  reg={reg:2d} util={rep.utilization:.3f} "
              f"mapm={rep.mapm:.3f} tops/w="
              f"{tops_per_watt(rep.stats.macs, e.total_j):.3f} "
              f"deadlock_breaks={rep.stats.deadlock_breaks}")

    print("\nPE-array shape sweep (reg=8):")
    for am, an in ((8, 8), (16, 16), (32, 32), (8, 32)):
        rep = run_gemm(x, w, AcceleratorConfig(array_m=am, array_n=an))
        e = energy_from_stats(rep.stats)
        print(f"  {am:2d}x{an:<2d} util={rep.utilization:.3f} "
              f"mapm={rep.mapm:.3f} tops/w="
              f"{tops_per_watt(rep.stats.macs, e.total_j):.3f}")
    print("OK")


if __name__ == "__main__":
    main()
