"""Paged KV cache: paged-vs-contiguous token equivalence, allocator
invariants (property-tested via the offline hypothesis shim), graceful
out-of-pages admission, typed rejection, per-request top_k."""
import warnings

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.serve import (PagedKVCache, RequestRejected, ServeEngine,
                         poisson_trace)


def _run_tokens(cfg, *, sparsity, trace, **engine_kw):
    eng = ServeEngine(cfg, num_slots=2, max_len=32, sparsity=sparsity,
                      seed=0, **engine_kw)
    reqs = [eng.submit(**spec) for spec in trace]
    eng.run()
    return [r.tokens for r in reqs], eng


def _mixed_trace(cfg, n=6):
    """Mixed request lengths: prompts 1..4, budgets 3..12 tokens."""
    return poisson_trace(n, rate=0.7, seed=2, vocab_size=cfg.vocab_size,
                         prompt_len=(1, 4), max_new=(3, 12))


# ------------------------------------------------------- equivalence -------


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-4b",
                                  "granite-moe-3b-a800m"])
@pytest.mark.parametrize("sparsity", [0.0, 0.75])
def test_paged_matches_contiguous_tokens(arch, sparsity):
    """The paged engine is token-identical to the contiguous engine on
    identical mixed-length traces — across full attention, sliding
    windows (gemma3's local blocks ring through pages) and MoE, pruned
    or not.  page_len divides both max_len and the smoke window, so the
    gathered page view reconstructs the contiguous cache bit-for-bit."""
    cfg = get_smoke_config(arch)
    trace = _mixed_trace(cfg)
    cont, _ = _run_tokens(cfg, sparsity=sparsity, trace=trace)
    paged, eng = _run_tokens(cfg, sparsity=sparsity, trace=trace,
                             paged=True, page_len=8)
    assert paged == cont
    assert all(toks for toks in paged)
    assert eng.report()["paging"]["paged"] is True


def test_tight_pool_queues_and_still_matches():
    """A pool far below worst case forces out-of-pages queueing; every
    request still completes with the same tokens as the contiguous
    engine (greedy decode is schedule-invariant per request)."""
    cfg = get_smoke_config("olmo-1b")
    trace = _mixed_trace(cfg)
    cont, _ = _run_tokens(cfg, sparsity=0.0, trace=trace)
    paged, eng = _run_tokens(cfg, sparsity=0.0, trace=trace, paged=True,
                             page_len=8, page_pool_tokens=16)
    assert paged == cont
    pg = eng.report()["paging"]
    assert pg["pages_total"] == 2 and pg["pages_peak"] <= 2
    assert pg["pages_in_use"] == 0          # drained: all pages freed


# ------------------------------------------------ admission / rejection ----


def test_oversized_request_raises_typed_error():
    """submit() must reject (typed) instead of assert-killing the
    process, and the engine must keep serving afterwards."""
    cfg = get_smoke_config("olmo-1b")
    eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0)
    with pytest.raises(RequestRejected):
        eng.submit([1] * 4, max_new_tokens=16)
    with pytest.raises(RequestRejected):
        eng.submit([], max_new_tokens=2)
    req = eng.submit([1], max_new_tokens=3)
    eng.run()
    assert len(req.tokens) == 3


def test_impossible_page_need_rejected_queueable_need_queued():
    """Larger-than-pool requests are rejected at submit; pool-sized
    requests queue through out-of-pages instead of crashing."""
    cfg = get_smoke_config("olmo-1b")
    eng = ServeEngine(cfg, num_slots=4, max_len=32, seed=0, paged=True,
                      page_len=8, page_pool_tokens=16)
    with pytest.raises(RequestRejected):
        eng.submit([1], max_new_tokens=32)   # needs 4 pages, pool holds 2
    reqs = [eng.submit([1, 2], max_new_tokens=10) for _ in range(4)]
    eng.run()
    assert all(len(r.tokens) == 10 for r in reqs)
    # 2 pages per request, 2-page pool: admissions were serialised
    admits = sorted(r.admit_step for r in reqs)
    assert admits == sorted(set(admits)), "requests ran concurrently " \
        "despite the pool only fitting one"


def test_no_attn_arch_falls_back_with_reason():
    cfg = get_smoke_config("rwkv6-3b")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0, paged=True)
    assert eng.page_len == 0
    assert "no attention blocks" in eng.paging_fallback
    assert any("contiguous" in str(w.message) for w in caught)
    req = eng.submit([1], max_new_tokens=3)
    eng.run()
    assert len(req.tokens) == 3
    assert eng.report()["paging"]["paged"] is False


# ------------------------------------------------- allocator properties ----


def _check_invariants(kv):
    for b, pool in kv.pools.items():
        mapped = pool.table[pool.table != 0]
        # no double allocation: every mapped page id is unique...
        assert len(set(mapped.tolist())) == len(mapped), \
            f"{b}: page aliased across slots"
        # ...and disjoint from the free list (no double free)
        assert not set(mapped.tolist()) & set(pool.free), \
            f"{b}: page both mapped and free"
        # conservation: free + mapped == pool, ids in [1, pool_pages]
        assert len(pool.free) + len(mapped) == pool.pool_pages, \
            f"{b}: pages leaked"
        assert pool.in_use == len(mapped)
        if len(mapped):
            assert mapped.min() >= 1 and mapped.max() <= pool.pool_pages
        # commitment never exceeds the pool
        assert 0 <= pool.committed <= pool.pool_pages


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 30), min_size=1, max_size=12),
       st.integers(8, 64), st.sampled_from([4, 8, 16]))
def test_allocator_invariants_under_random_load(needs, pool_tokens,
                                                page_len):
    """No double-free, no cross-slot page aliasing, free-list
    conservation — under random request sizes, pool budgets and page
    sizes, with full admit/ensure/retire lifecycles."""
    cfg = get_smoke_config("gemma3-4b")   # windowed + global blocks
    kv = PagedKVCache(cfg, num_slots=3, max_len=32, page_len=page_len,
                      pool_tokens=pool_tokens)
    # drop requests the pool can never hold (engine rejects those typed)
    needs = [n for n in needs if kv.possible(n)]
    active = {}                            # slot -> [next position, need]
    free_slots = [0, 1, 2]
    guard = 0
    while (needs or active) and guard < 500:
        guard += 1
        for slot, (pos, need) in list(active.items()):
            if pos >= need:                # all positions written: retire
                kv.retire(slot)
                free_slots.append(slot)
                del active[slot]
        while needs and free_slots:
            need = needs[0]
            if not kv.reserve(need):       # out of pages: head queues
                break
            needs.pop(0)
            slot = free_slots.pop()
            kv.admit(slot, need)
            active[slot] = [0, need]
        for slot in list(active):
            pos, need = active[slot]
            kv.ensure(slot, pos)
            active[slot][0] = pos + 1
        _check_invariants(kv)
    assert not needs and not active, "allocator stalled under load"
    for pool in kv.pools.values():
        assert pool.in_use == 0 and pool.committed == 0
        assert len(pool.free) == pool.pool_pages


# ---------------------------------------------------- per-request top_k ----


def test_per_request_top_k_mixes_in_one_batch():
    """top_k is per-slot inside the jitted sampler: a top_k=1 sampled
    request is exactly greedy while a wider request (same seed) samples,
    in the same batch, with the engine default still honoured."""
    cfg = get_smoke_config("olmo-1b")

    def run():
        eng = ServeEngine(cfg, num_slots=3, max_len=32, seed=0, top_k=4)
        g = eng.submit([5], max_new_tokens=6)
        k1 = eng.submit([5], max_new_tokens=6, temperature=1.0, seed=7,
                        top_k=1)
        kd = eng.submit([5], max_new_tokens=6, temperature=1.0, seed=7)
        eng.run()
        return g.tokens, k1.tokens, kd.tokens

    g, k1, kd = run()
    assert k1 == g                     # top-1 sampling == argmax
    assert kd != g                     # engine-default k=4 really samples
    assert run() == (g, k1, kd)        # deterministic per-request streams


def test_top_k_zero_override_disables_engine_default():
    cfg = get_smoke_config("olmo-1b")
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0, top_k=1)
    full = eng.submit([5], max_new_tokens=8, temperature=1.5, seed=3,
                      top_k=0)          # explicit 0: full distribution
    trunc = eng.submit([5], max_new_tokens=8, temperature=1.5, seed=3)
    eng.run()
    g = ServeEngine(cfg, num_slots=1, max_len=32, seed=0)
    greedy = g.submit([5], max_new_tokens=8)
    g.run()
    assert trunc.tokens == greedy.tokens   # default k=1 == greedy
    assert full.tokens != greedy.tokens    # k=0 samples the full dist
