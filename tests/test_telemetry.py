"""Serving telemetry: registry, spans, events, and the off==on contract.

Pillars:

* **registry units** — Counter/Gauge/Histogram semantics, render order,
  the NaN-free JSON snapshot, and the Prometheus text page;
* **clock regression** — the serving clock starts once, after warmup,
  via the one idempotent ``Clock.start()``: host time spent *before*
  the run (the old double-``_t0``-reset warmup-leak surface) never
  lands in ``report()["wall_s"]``;
* **schema snapshot** — ``report()`` rendered from the registry keeps
  every pre-existing section and field with unchanged names and types
  across the knob matrix ({paged, prefix_reuse, preempt, audit} ×
  {packed, dense}), so downstream bench parsers can't silently break;
* **off == on** — telemetry-off holds no span/event objects (the hot
  path stays allocation-free) and serves bit-identical tokens to a
  telemetry-on run of the same trace;
* **artifacts** — the Chrome trace validates (phases nest in steps,
  no overlap, lifecycle order), the JSONL event log matches the schema
  with monotonic timestamps, and ``_bench_io`` merges sections
  atomically without clobbering its neighbours.
"""
import json
import math
import pathlib
import sys
import time

import pytest

from repro.configs import get_smoke_config
from repro.serve import (Clock, MetricsRegistry, ServeEngine,
                         poisson_trace, validate_events, validate_trace)
from repro.serve.telemetry import (EVENT_KINDS, PHASES, validate_event)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "benchmarks"))
import _bench_io  # noqa: E402

CFG = get_smoke_config("olmo-1b")


def _engine(**kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("sparsity", 0.5)
    return ServeEngine(CFG, seed=0, **kw)


def _run(eng, requests=3, seed=0):
    trace = poisson_trace(requests, rate=0.5, seed=seed,
                          vocab_size=CFG.vocab_size, prompt_len=(1, 4),
                          max_new=(2, 5))
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)
        rep = eng.run()
    return rep, [(r.rid, r.state.name, list(r.tokens))
                 for r in eng.requests]


# ------------------------------------------------------- registry units ----

def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c", help="a counter")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(AssertionError):
        c.inc(-1)

    g = reg.gauge("g")
    g.set(7)
    assert g.value == 7
    backing = {"v": 1}
    gf = reg.gauge("gf", lambda: backing["v"])
    backing["v"] = 42
    assert gf.value == 42          # callback gauges are never stale
    with pytest.raises(AssertionError):
        gf.set(0)

    h = reg.histogram("h", seed=3)
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    assert h.count == 3 and h.sum == pytest.approx(6.0)
    assert h.mean == pytest.approx(2.0)
    assert h.percentiles()["p50"] == pytest.approx(2.0)

    with pytest.raises(AssertionError):
        reg.counter("c")           # duplicate names are bugs
    assert reg.names == ["c", "g", "gf", "h"]


def test_registry_views_render_in_order():
    reg = MetricsRegistry()
    reg.view("b", lambda: 2)
    reg.view("a", lambda: {"nested": 1})
    assert list(reg.render()) == ["b", "a"]
    assert reg.render()["a"] == {"nested": 1}
    with pytest.raises(AssertionError):
        reg.view("b", lambda: 0)


def test_snapshot_is_strict_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    reg.gauge("reason", lambda: "because")
    reg.histogram("empty")         # NaN percentiles -> None, not NaN
    snap = reg.snapshot()
    assert snap["c"] == 2
    assert snap["reason"] == "because"
    assert snap["empty"]["mean"] is None
    json.dumps(snap, allow_nan=False)   # strict JSON round-trips
    p = tmp_path / "m.json"
    reg.write(str(p))
    doc = json.loads(p.read_text())
    assert doc["schema"] == "repro.serve.metrics/v1"
    assert doc["metrics"]["c"] == 2


def test_prometheus_text_format(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tokens.generated", help="tokens").inc(9)
    reg.gauge("queue.depth", lambda: 3)
    reg.gauge("fallback.reason", lambda: "strings are skipped")
    h = reg.histogram("step.wall_s")
    h.observe(0.25)
    text = reg.to_prometheus()
    assert "# TYPE repro_serve_tokens_generated counter" in text
    assert "repro_serve_tokens_generated 9" in text
    assert "repro_serve_queue_depth 3" in text
    assert "strings are skipped" not in text
    assert 'repro_serve_step_wall_s{quantile="0.5"} 0.25' in text
    assert "repro_serve_step_wall_s_count 1" in text
    p = tmp_path / "m.prom"
    reg.write(str(p))
    assert p.read_text() == text


# ------------------------------------------------------ clock regression ----

def test_clock_starts_once():
    clk = Clock()
    assert not clk.started
    clk.start()
    t0 = clk.t0
    time.sleep(0.01)
    clk.start()                    # idempotent: second start is a no-op
    assert clk.t0 == t0
    assert clk.now() >= 0.0
    assert Clock().now_or_zero() == 0.0


def test_warmup_never_leaks_into_wall(monkeypatch):
    """The regression the one-``Clock`` refactor pins: host time spent
    between warmup and the first step (compile tails, test-harness
    sleeps — anything pre-serving) must not appear in ``wall_s``."""
    eng = _engine()
    with eng.mesh:
        eng.warmup()
        time.sleep(0.3)            # pre-serving dead time
        assert not eng._clock.started
        eng.submit([1, 2, 3], max_new_tokens=2)
        rep = eng.run()
    assert eng._clock.started
    assert rep["wall_s"] < 0.3, (
        f"wall_s={rep['wall_s']:.3f}s includes pre-run dead time")


# -------------------------------------------------------- schema snapshot ----

_PCT = {"p50": float, "p99": float}

# the pre-registry report() layout: every section and field, pinned.
# (value `dict` means "a dict with these exact keys checked recursively";
# a type tuple means isinstance check; None means "may be anything")
_SCHEMA = {
    "requests": int,
    "retained_requests": int,
    "generated_tokens": int,
    "steps": int,
    "wall_s": float,
    "tok_per_s": float,
    "latency_s": _PCT,
    "first_token_s": _PCT,
    "ttft": {"queue_s": _PCT, "prefill_s": _PCT, "first_decode_s": _PCT},
    "prefill": {"enabled": bool, "fallback": None, "prefill_steps": int,
                "decode_steps": int, "chunk": int, "calls": int,
                "tokens_prefilled": int, "in_flight": int,
                "lane_utilization": None},
    "prefix_reuse": {"enabled": bool, "fallback": None,
                     "ttft_hit_s": _PCT, "ttft_miss_s": _PCT,
                     "hit_requests": int, "miss_requests": int,
                     "preempt": {"enabled": bool, "fallback": None,
                                 "count": int, "recomputed_tokens": int}},
    "slot_occupancy": float,
    "weight_sparsity": float,
    "head_compression": float,
    "head_fallback": None,
    "weight_stream": {"packed_tensors": int, "fallback_tensors": int,
                      "sparse_bytes_per_step": (int, float),
                      "dense_bytes_per_step": (int, float),
                      "reduction": float},
    "traffic": {"per_role": dict,
                "weight": {"sparse_bytes_per_step": int,
                           "dense_bytes_per_step": int,
                           "reduction": float},
                "kv": {"line_bytes_per_token": int, "read_bytes": int,
                       "write_bytes": int, "prefix_saved_bytes": int},
                "phases": {"decode": {"steps": int, "weight_bytes": int,
                                      "kv_read_bytes": int,
                                      "kv_write_bytes": int},
                           "prefill": {"calls": int, "weight_bytes": int,
                                       "kv_read_bytes": int,
                                       "kv_write_bytes": int}},
                "energy": {"macs_per_token": int, "pj_per_token": float,
                           "pj_per_token_dense": float,
                           "tops_per_watt": float,
                           "tops_per_watt_dense": float},
                "roofline": dict,
                "crosscheck": None},
    "paging": {"paged": bool, "fallback": None,
               "reserved_kv_bytes": int, "contiguous_kv_bytes": int,
               "reserved_reduction": float},
    "cache_resets": int,
    "lifecycle": {"deadline_ms": None, "max_queue": None,
                  "ttft_budget_ms": None, "max_preempts": int,
                  "cancelled": int, "expired": int, "shed": int,
                  "forced_preempts": int, "wasted_tokens": int,
                  "estimated_ttft_s": None, "terminal_states": dict,
                  "quarantined": dict},
    "fallbacks": dict,
}


def _check(section, spec, path=""):
    if spec is None:
        return
    if isinstance(spec, dict):
        assert isinstance(section, dict), f"{path}: not a section"
        for key, sub in spec.items():
            assert key in section, f"{path}.{key}: field missing"
            _check(section[key], sub, f"{path}.{key}")
        return
    # bools are ints in python; pin them apart so flags stay flags
    if spec is int:
        assert (isinstance(section, int)
                and not isinstance(section, bool)), \
            f"{path}: {type(section).__name__} != int"
    elif spec is float:
        # NaN is legal (empty-histogram percentiles pre-date the
        # registry); the *type* is what downstream parsers rely on
        assert isinstance(section, float), \
            f"{path}: {type(section).__name__} != float"
    else:
        assert isinstance(section, spec), \
            f"{path}: {type(section).__name__} != {spec}"


_KNOBS = [
    {},
    {"paged": True, "page_len": 8},
    {"paged": True, "page_len": 8, "prefill_chunk": 8,
     "prefix_reuse": True},
    {"paged": True, "page_len": 8, "preempt": True},
    {"audit": True},
]


@pytest.mark.parametrize("stream", [True, False],
                         ids=["packed", "dense"])
@pytest.mark.parametrize("knobs", _KNOBS,
                         ids=["plain", "paged", "prefix", "preempt",
                              "audit"])
def test_report_schema_survives_registry(knobs, stream):
    eng = _engine(stream_weights=stream, bitmap_head=stream, **knobs)
    rep, _ = _run(eng, requests=2)
    assert list(rep) == list(_SCHEMA), "top-level keys or order changed"
    _check(rep, _SCHEMA)
    if knobs.get("audit"):
        assert rep["lifecycle"]["audit"]["steps_checked"] > 0
    if knobs.get("paged"):
        assert rep["paging"]["paged"] is True
        assert "fragmentation" in rep["paging"]
    if knobs.get("prefix_reuse"):
        assert "hits" in rep["prefix_reuse"]


# ------------------------------------------------------------ off == on ----

def test_telemetry_off_is_allocation_free_and_identical(tmp_path):
    eng_off = _engine(paged=True, page_len=8, prefill_chunk=8,
                      prefix_reuse=True, audit=True)
    assert eng_off.telemetry is None
    assert eng_off.spans is None and eng_off.events is None
    _, served_off = _run(eng_off, requests=4)

    eng_on = _engine(paged=True, page_len=8, prefill_chunk=8,
                     prefix_reuse=True, audit=True,
                     trace_out=str(tmp_path / "t.json"),
                     events_out=str(tmp_path / "e.jsonl"),
                     metrics_out=str(tmp_path / "m.json"))
    _, served_on = _run(eng_on, requests=4)
    assert served_on == served_off
    paths = eng_on.close()
    assert [pathlib.Path(p).name for p in paths] == \
        ["t.json", "e.jsonl", "m.json"]
    assert eng_on.close() == []    # idempotent

    stats = validate_trace(str(tmp_path / "t.json"))
    assert stats["steps"] > 0 and stats["requests"] == 4
    assert stats["agg_coverage"] > 0.5
    n = validate_events(str(tmp_path / "e.jsonl"))
    assert n > 0


def test_trace_spans_and_phases(tmp_path):
    eng = _engine(prefill_chunk=8, trace_out=str(tmp_path / "t.json"))
    _run(eng, requests=3)
    eng.close()
    from repro.serve import load_trace
    events = load_trace(str(tmp_path / "t.json"))
    names = {e["name"] for e in events
             if e.get("ph") == "X" and e.get("cat") == "phase"}
    assert names <= set(PHASES)
    assert {"schedule", "decode", "sample", "host_sync"} <= names
    req_names = {e["name"] for e in events
                 if e.get("ph") == "X" and e.get("cat") == "request"}
    assert req_names <= {"QUEUED", "PREFILL", "DECODE"}
    # registry histograms accumulated the same spans (step() calls,
    # not report()["steps"] — that gauge includes idle fast-forward)
    h = eng.metrics.get("step.wall_s")
    assert h.count == eng.spans.steps > 0
    cov = eng.metrics.get("step.phase_coverage")
    assert cov.mean > 0.5


def test_event_log_schema(tmp_path):
    path = str(tmp_path / "e.jsonl")
    eng = _engine(deadline_ms=1e9, events_out=path)
    _run(eng, requests=3)
    eng.close()
    n = validate_events(path)
    assert n > 0
    kinds = set()
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            kinds.add(rec["kind"])
            assert rec["kind"] in EVENT_KINDS
    assert {"submit", "admit", "first_token", "done"} <= kinds
    with pytest.raises(ValueError):
        validate_event({"t": 0.0, "step": 0, "kind": "nope"})
    with pytest.raises(ValueError):
        validate_event({"step": 0, "kind": "done"})


# -------------------------------------------------------------- bench io ----

def test_bench_io_merge_preserves_sections(tmp_path):
    path = str(tmp_path / "BENCH.json")
    _bench_io.merge_section(path, "paging", {"x": 1}, verbose=False)
    _bench_io.merge_section(path, "prefill", {"y": 2}, wall_s=1.5,
                            verbose=False)
    doc = _bench_io.load_bench(path)
    assert doc["paging"] == {"x": 1}           # neighbour preserved
    assert doc["prefill"] == {"y": 2, "bench_wall_s": 1.5}
    assert not list(tmp_path.glob(".bench_*")), "tempfile left behind"


def test_bench_timer_records_registry(tmp_path):
    reg = MetricsRegistry()
    with _bench_io.bench_timer("demo", registry=reg) as timing:
        time.sleep(0.01)
    assert timing.wall_s >= 0.01
    h = reg.get("bench.demo.wall_s")
    assert h.count == 1 and h.sum == pytest.approx(timing.wall_s)
    with _bench_io.bench_timer("demo", registry=reg):
        pass
    assert h.count == 2                        # same histogram reused
