"""Request-lifecycle hardening: deadlines, cancellation, load shedding.

Four pillars:

* the typed error taxonomy — every non-DONE outcome carries a
  ``ServeError`` subclass, exported from ``repro.serve`` and raised (or
  recorded on the request) instead of crashing the process;
* cancellation at every lifecycle stage — queued, mid-prefill,
  mid-decode, and mid-preempt-replay — releases pages and prefix-cache
  references exactly (allocator audit clean, zero pages in use after
  drain) and never perturbs the surviving requests' tokens (random
  cancel interleavings via the offline hypothesis shim);
* deadline expiry (queued and mid-flight) and admission-control load
  shedding produce the EXPIRED / SHED terminal states with
  ``DeadlineExceeded`` / ``ServeOverloaded`` recorded;
* bounded preemption — a forced-preemption storm cannot preempt any
  request more than ``max_preempts`` times (the pinned reserved-page
  fast path), and every request still finishes with the undisturbed
  run's exact tokens.
"""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.serve import (AuditViolation, DeadlineExceeded, FaultPlan,
                         OutOfPages, Request, RequestRejected,
                         RequestState, ServeEngine, ServeError,
                         ServeOverloaded, TERMINAL_STATES)

CFG = get_smoke_config("olmo-1b")


def _engine(**kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("sparsity", 0.5)
    return ServeEngine(CFG, seed=0, **kw)


def _drain(eng, cancels=None):
    """Step until drained, firing ``cancels``: {step: [rid, ...]}."""
    cancels = cancels or {}
    step = 0
    while eng.scheduler.has_work:
        for rid in cancels.get(step, []):
            eng.cancel(rid)
        eng.step()
        step += 1
        assert step < 10_000, "engine failed to drain"
    return {r.rid: list(r.tokens) for r in eng.requests}


PROMPTS = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [4, 5, 6], [1, 2, 3, 4, 5, 6],
           [9, 8, 7, 6, 5]]


def _submit_all(eng, n=4, max_new=6):
    return [eng.submit(PROMPTS[i % len(PROMPTS)], max_new,
                       arrival=float(i)) for i in range(n)]


# ------------------------------------------------------ error taxonomy ----


def test_error_hierarchy():
    assert issubclass(RequestRejected, ServeError)
    assert issubclass(RequestRejected, ValueError)   # legacy contract
    assert issubclass(OutOfPages, ServeError)
    assert issubclass(OutOfPages, RuntimeError)      # legacy contract
    assert issubclass(ServeOverloaded, ServeError)
    assert issubclass(DeadlineExceeded, ServeError)
    assert issubclass(AuditViolation, ServeError)
    assert issubclass(AuditViolation, AssertionError)
    e = ServeOverloaded("queue full", queue_depth=7, est_ttft_s=0.5)
    assert e.queue_depth == 7 and e.est_ttft_s == 0.5
    assert "queue full" in str(e)


def test_state_machine_legality():
    req = Request(rid=0, prompt=[1], max_new_tokens=1)
    req.transition(RequestState.WAITING)
    req.transition(RequestState.ACTIVE)
    with pytest.raises(AuditViolation):
        req.transition(RequestState.SHED)      # ACTIVE can't be shed
    req.transition(RequestState.DONE)
    assert req.terminal
    with pytest.raises(AuditViolation):
        req.transition(RequestState.WAITING)   # terminal is final
    assert TERMINAL_STATES == {RequestState.DONE, RequestState.CANCELLED,
                               RequestState.EXPIRED, RequestState.SHED}


# -------------------------------------------------------- cancellation ----


def test_cancel_queued_and_unknown_rid():
    eng = _engine()
    reqs = _submit_all(eng, n=4)
    # rid 3 is still queued (arrival 3.0, no steps run)
    assert eng.cancel(reqs[3].rid)
    assert reqs[3].state is RequestState.CANCELLED
    assert reqs[3].tokens == []
    assert reqs[3].error is None               # client asked: no error
    assert reqs[3].result() == []
    assert not eng.cancel(reqs[3].rid)         # already terminal
    assert not eng.cancel(999)                 # unknown
    toks = _drain(eng)
    assert all(reqs[i].state is RequestState.DONE for i in range(3))
    assert eng.report()["lifecycle"]["cancelled"] == 1
    assert toks[reqs[3].rid] == []


@pytest.mark.parametrize("kw", [
    dict(),                                                # contiguous
    dict(paged=True, page_len=8, prefill_chunk=4),         # mid-prefill
    dict(paged=True, page_len=8, prefix_reuse=True,
         preempt=True, prefill_chunk=4),                   # full stack
])
def test_cancel_mid_flight_no_leak_no_perturbation(kw):
    """Cancel one request while it is actively decoding (or prefilling):
    the survivors' tokens match the undisturbed run exactly, and the
    paged allocator audits clean with zero pages in use after drain."""
    eng0 = _engine(**kw)
    reqs0 = _submit_all(eng0)
    base = _drain(eng0)
    eng = _engine(**kw)
    reqs = _submit_all(eng)
    victim = reqs[1].rid
    toks = _drain(eng, cancels={2: [victim]})
    assert reqs[1].state in (RequestState.CANCELLED, RequestState.DONE)
    if reqs[1].state is RequestState.CANCELLED:
        # partial tokens are a prefix of what it would have generated
        assert base[victim][:len(toks[victim])] == toks[victim]
    for r in reqs:
        if r.rid != victim:
            assert r.state is RequestState.DONE
            assert toks[r.rid] == base[r.rid], f"rid {r.rid} perturbed"
    if eng.page_len:
        eng.kv.flush_prefix()
        eng.kv.audit()
        for pool in eng.kv.pools.values():
            assert not pool.ref and not pool.held


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 3), st.integers(0, 12), st.integers(0, 3))
def test_cancel_random_interleavings(victim_i, cancel_step, extra):
    """Random (victim, step) cancel interleavings over the full paged +
    reuse + preempt + prefill stack: survivors always match the
    undisturbed baseline and nothing leaks."""
    kw = dict(paged=True, page_len=8, page_pool_tokens=96,
              prefix_reuse=True, preempt=True, prefill_chunk=4)
    eng0 = _engine(**kw)
    reqs0 = _submit_all(eng0)
    base = _drain(eng0)
    eng = _engine(**kw)
    reqs = _submit_all(eng)
    victim = reqs[victim_i].rid
    cancels = {cancel_step: [victim]}
    if extra != victim_i:           # sometimes cancel a second request
        cancels.setdefault(cancel_step + 1, []).append(reqs[extra].rid)
    toks = _drain(eng, cancels=cancels)
    cancelled = {rid for rids in cancels.values() for rid in rids}
    for r in reqs:
        assert r.terminal
        if r.state is RequestState.DONE and r.rid not in cancelled:
            assert toks[r.rid] == base[r.rid], f"rid {r.rid} perturbed"
    eng.kv.flush_prefix()
    eng.kv.audit()
    for pool in eng.kv.pools.values():
        assert not pool.ref and not pool.held
    lc = eng.report()["lifecycle"]
    assert lc["cancelled"] == sum(1 for r in reqs
                                  if r.state is RequestState.CANCELLED)


def test_cancel_mid_preempt_replay():
    """Cancel a request while it is re-queued behind a preemption (its
    ``t_preempt`` mark is set, state WAITING): the requeue entry leaves
    the queue, pages stay clean, survivors undisturbed."""
    kw = dict(paged=True, page_len=8, prefill_chunk=4, prefix_reuse=True,
              preempt=True)
    eng0 = _engine(**kw)
    reqs0 = _submit_all(eng0)
    base = _drain(eng0)

    eng = _engine(**kw)
    reqs = _submit_all(eng)
    for _ in range(4):
        eng.step()
    # preempt the youngest active slot between steps: its request sits
    # in the requeue (state WAITING, t_preempt marked) when we cancel
    slot = max(eng.scheduler.active,
               key=lambda s: int(eng._admit_seq[s]))
    victim = eng.scheduler.active[slot]
    eng._preempt_slot(slot)
    assert victim.state is RequestState.WAITING and victim.t_preempt
    assert eng.cancel(victim.rid)
    assert victim.state is RequestState.CANCELLED
    toks = _drain(eng)
    for r in reqs:
        if r.rid != victim.rid:
            assert r.state is RequestState.DONE
            assert toks[r.rid] == base[r.rid]
    eng.kv.flush_prefix()
    eng.kv.audit()
    for pool in eng.kv.pools.values():
        assert not pool.ref and not pool.held


# ------------------------------------------------------------ deadlines ----


def test_deadline_expires_queued_request():
    eng = _engine(num_slots=1, max_len=64)
    blocker = eng.submit(list(range(1, 5)), 30, arrival=0.0)
    starved = eng.submit([1, 2, 3], 5, arrival=0.0, deadline_ms=0.0)
    _drain(eng)
    assert blocker.state is RequestState.DONE
    assert starved.state is RequestState.EXPIRED
    assert isinstance(starved.error, DeadlineExceeded)
    with pytest.raises(DeadlineExceeded):
        starved.result()
    assert eng.report()["lifecycle"]["expired"] == 1


def test_deadline_expires_mid_decode_keeps_partial_tokens():
    eng = _engine()
    req = eng.submit([1, 2, 3], 40, arrival=0.0, deadline_ms=1e9)
    ok = eng.submit([4, 5, 6], 4, arrival=0.0)     # no deadline
    for _ in range(6):                             # let it decode a bit
        eng.step()
    assert req.state is RequestState.ACTIVE
    req.deadline_ms = 0.0                          # budget just ran out
    eng.step()
    assert req.state is RequestState.EXPIRED
    assert 0 < len(req.tokens) < 40                # cut off mid-flight
    assert isinstance(req.error, DeadlineExceeded)
    assert "mid-flight" in str(req.error)
    _drain(eng)
    assert ok.state is RequestState.DONE and len(ok.tokens) == 4


def test_generous_deadline_never_fires():
    eng = _engine(deadline_ms=600_000.0)           # engine-wide default
    reqs = _submit_all(eng)
    _drain(eng)
    assert all(r.state is RequestState.DONE for r in reqs)
    assert eng.report()["lifecycle"]["expired"] == 0


# --------------------------------------------------------- load shedding ----


def test_submit_time_shedding_raises_typed():
    eng = _engine(num_slots=1, max_queue=2)
    eng.submit([1, 2], 20, arrival=0.0)            # queues (depth 0)
    eng.submit([3, 4], 4, arrival=0.0)             # depth 1 < 2: accepted
    with pytest.raises(ServeOverloaded) as ei:
        eng.submit([5, 6], 4, arrival=0.0)         # depth 2 >= 2: shed
    assert ei.value.queue_depth >= 2
    shed_before = eng.report()["lifecycle"]["shed"]
    assert shed_before >= 1
    _drain(eng)                                    # keeps serving
    assert eng.report()["lifecycle"]["shed"] == shed_before


def test_due_time_shedding_records_silently():
    eng = _engine(num_slots=1, max_len=64, max_queue=1)
    blocker = eng.submit(list(range(1, 5)), 24, arrival=0.0)
    late = [eng.submit([1, 2, 3], 4, arrival=2.0) for _ in range(3)]
    _drain(eng)
    assert blocker.state is RequestState.DONE
    states = [r.state for r in late]
    assert RequestState.SHED in states
    for r in late:
        assert r.terminal
        if r.state is RequestState.SHED:
            assert isinstance(r.error, ServeOverloaded)
            with pytest.raises(ServeOverloaded):
                r.result()
    lc = eng.report()["lifecycle"]
    assert lc["shed"] == states.count(RequestState.SHED)
    assert lc["terminal_states"].get("SHED") == lc["shed"]


def test_no_shedding_configured_never_rejects_busy_engine():
    eng = _engine(num_slots=1)
    reqs = [eng.submit([1, 2, 3], 6, arrival=0.0) for _ in range(6)]
    _drain(eng)
    assert all(r.state is RequestState.DONE for r in reqs)
    assert eng.report()["lifecycle"]["shed"] == 0


# ------------------------------------------------- bounded preemption ----


def test_forced_preempt_storm_respects_max_preempts():
    """A preemption storm cannot preempt any request more than
    ``max_preempts`` times: once pinned, a request holds a worst-case
    (reserved-page) commitment and is excluded from victim selection,
    so it finishes — with the undisturbed run's exact tokens."""
    kw = dict(paged=True, page_len=8, prefix_reuse=True, preempt=True,
              prefill_chunk=4, max_len=64)
    eng0 = _engine(**kw)
    reqs0 = _submit_all(eng0, max_new=8)
    base = _drain(eng0)

    plan = FaultPlan(seed=0)
    for s in range(2, 26, 2):
        plan.force_preempt(step=s, count=1)
    eng = _engine(**kw, max_preempts=2, faults=plan, audit=True)
    reqs = _submit_all(eng, max_new=8)
    toks = _drain(eng)
    assert eng._forced_preempts > 0
    for r in reqs:
        assert r.state is RequestState.DONE
        assert len(r.t_preempt) <= 2, f"rid {r.rid} over-preempted"
        assert toks[r.rid] == base[r.rid], f"rid {r.rid} diverged"
    eng.kv.flush_prefix()
    eng.kv.audit()
    for pool in eng.kv.pools.values():
        assert not pool.ref and not pool.held


# ------------------------------------------------------ fallback dedup ----


def test_fallback_warnings_dedupe_and_mirror_into_report():
    eng = _engine()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng._warn_fallback("k", "some reason", "message one")
        eng._warn_fallback("k", "some reason", "message one")
        eng._warn_fallback("k", "other reason", "message two")
    assert [str(x.message) for x in w] == ["message one", "message two"]
    assert eng.fallbacks["k"] == "other reason"     # latest wins
    assert eng.report()["fallbacks"]["k"] == "other reason"


def test_init_fallbacks_are_recorded():
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        eng = _engine(prefix_reuse=True)            # needs paged: falls back
    assert "prefix_reuse" in eng.fallbacks
    assert eng.report()["prefix_reuse"]["fallback"] is not None


# ------------------------------------------------------ taxonomy totals ----


def test_terminal_taxonomy_partitions_history():
    eng = _engine(num_slots=1, max_len=64, max_queue=2)
    eng.submit(list(range(1, 5)), 20, arrival=0.0)
    eng.submit([1, 2], 4, arrival=0.0, deadline_ms=0.0)   # will expire
    doomed = eng.submit([3, 4], 4, arrival=1.0)
    eng.submit([5, 6], 4, arrival=1.0)
    cancel_me = eng.submit([7, 8], 4, arrival=2.0)
    eng.cancel(cancel_me.rid)
    _drain(eng)
    lc = eng.report()["lifecycle"]
    tax = lc["terminal_states"]
    assert sum(tax.values()) == len(eng.requests)
    assert tax.get("CANCELLED", 0) == lc["cancelled"] == 1
    assert tax.get("EXPIRED", 0) == lc["expired"]
    assert lc["wasted_tokens"] >= 0
