"""Training substrate: optimizer, checkpointing, sparse training, pipeline."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch
from repro.launch.steps import build_train_step
from repro.launch.train import train
from repro.models import init_params
from repro.sparse.pruning import global_l1_prune, sparsity_of
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib


def test_adamw_converges_on_quadratic():
    params = {"x": jnp.full((8,), 5.0)}
    state = opt_lib.init(params)
    cfg = opt_lib.OptConfig(lr=0.3, warmup_steps=1, total_steps=200,
                            weight_decay=0.0, clip_norm=100.0)
    for _ in range(100):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, m = opt_lib.update(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.2


def test_grad_clipping_bounds_update():
    params = {"x": jnp.zeros((4,))}
    state = opt_lib.init(params)
    cfg = opt_lib.OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0)
    grads = {"x": jnp.full((4,), 1e6)}
    _, _, metrics = opt_lib.update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(opt_lib.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt_lib.schedule(cfg, jnp.int32(100))) == pytest.approx(
        0.1, abs=1e-3)


def test_training_reduces_loss():
    """End-to-end mini-run on the synthetic copy-structured stream."""
    res = train("olmo-1b", smoke=True, steps=30, batch=8, seq=64, lr=3e-3)
    first = np.mean(res["losses"][:3])
    last = np.mean(res["losses"][-3:])
    assert last < first - 0.3, (first, last)


def test_checkpoint_roundtrip_and_resume(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(d, 10, tree)
    ckpt.save(d, 20, jax.tree.map(lambda x: x * 2, tree))
    assert ckpt.latest_step(d) == 20
    back = ckpt.restore(d, 20, tree)
    np.testing.assert_allclose(np.asarray(back["a"]),
                               np.asarray(tree["a"]) * 2)
    # uncommitted dirs are ignored
    os.makedirs(os.path.join(d, "step_30"))
    assert ckpt.latest_step(d) == 20


def test_checkpoint_gc_keeps_latest(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert sorted(ckpt.completed_steps(d)) == [4, 5]


def test_train_resume_from_checkpoint(tmp_path):
    d = str(tmp_path / "ck")
    train("olmo-1b", smoke=True, steps=6, batch=4, seq=32, ckpt_dir=d,
          ckpt_every=3)
    assert ckpt.latest_step(d) == 6
    # resume: runs only the remaining steps
    res = train("olmo-1b", smoke=True, steps=10, batch=4, seq=32,
                ckpt_dir=d, ckpt_every=100)
    assert len(res["losses"]) == 4


def test_masked_sparse_training_keeps_zeros():
    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params = global_l1_prune(params, 0.7)
    masks = jax.tree.map(lambda p: (p != 0).astype(p.dtype), params)
    s0 = sparsity_of(params)
    step = build_train_step(cfg, opt_lib.OptConfig(lr=1e-2, warmup_steps=1),
                            prune_masks=masks)
    opt_state = opt_lib.init(params)
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(r.integers(0, 256, (2, 16)), jnp.int32),
             "targets": jnp.asarray(r.integers(0, 256, (2, 16)), jnp.int32)}
    params, opt_state, _ = jax.jit(step)(params, opt_state, batch)
    assert abs(sparsity_of(params) - s0) < 1e-9


def test_pipeline_determinism_and_host_sharding():
    cfg = get_smoke_config("olmo-1b")
    dc = DataConfig(global_batch=8, seq_len=32, seed=3)
    a = synth_batch(cfg, dc, step=5)
    b = synth_batch(cfg, dc, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(cfg, dc, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # hosts get disjoint-but-complete slices (different rows)
    h0 = synth_batch(cfg, dc, step=5, host=0, num_hosts=2)
    h1 = synth_batch(cfg, dc, step=5, host=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher_orders_steps():
    cfg = get_smoke_config("olmo-1b")
    dc = DataConfig(global_batch=2, seq_len=16)
    pf = Prefetcher(cfg, dc, start_step=7)
    steps = [next(pf)[0] for _ in range(3)]
    pf.close()
    assert steps == [7, 8, 9]
