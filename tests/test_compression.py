"""Gradient compression: quantisation fidelity + DP psum parity (8 fake
devices, subprocess) + error-feedback convergence."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.compression import (compress_tree, decompress_tree,
                                     dequantize_int8, quantize_int8)


def test_quantize_roundtrip_error_bounded():
    r = np.random.default_rng(0)
    g = jnp.asarray(r.standard_normal((256,)) * 3.0, jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_exact_residual():
    r = np.random.default_rng(1)
    g = {"a": jnp.asarray(r.standard_normal((64,)), jnp.float32)}
    q, s, resid = compress_tree(g)
    back = decompress_tree(q, s)
    np.testing.assert_allclose(np.asarray(back["a"] + resid["a"]),
                               np.asarray(g["a"]), rtol=1e-6)


def test_error_feedback_convergence():
    """SGD on a quadratic with int8 grads + error feedback converges to the
    same optimum as exact grads."""
    x = jnp.full((16,), 4.0)
    err = jnp.zeros((16,))
    for _ in range(200):
        g = 2 * x + err
        q, s = quantize_int8(g)
        gq = dequantize_int8(q, s)
        err = g - gq
        x = x - 0.05 * gq
    assert float(jnp.abs(x).max()) < 0.05


def test_compressed_psum_matches_mean(tmp_path):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import (compressed_psum_grads,
                                             init_error_fb)
        mesh = jax.make_mesh((8,), ("data",))
        def grad_fn(params, batch):
            return {"w": jnp.mean(batch, axis=0) * params["w"]}
        fn = compressed_psum_grads(grad_fn, mesh, "data")
        params = {"w": jnp.ones((32,))}
        r = np.random.default_rng(0)
        batch = jnp.asarray(r.standard_normal((64, 32)), jnp.float32)
        err = init_error_fb({"w": jnp.zeros((32,))}, 8)
        grads, resid = fn(params, batch, err)
        exact = np.asarray(batch.reshape(8, 8, 32).mean(1).mean(0))
        got = np.asarray(grads["w"])
        print(json.dumps({"err": float(np.abs(got - exact).max()),
                          "scaleref": float(np.abs(exact).max())}))
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=300,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # int8 quantisation error bound: ~1/127 of per-shard max, psum-averaged
    assert res["err"] < 0.05 * max(res["scaleref"], 1.0), res
