"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.bitmap_spmm import bitmap_spmm, hbm_traffic_model
from repro.kernels.block_sparse import block_sparse_matmul
from repro.kernels.flash_attention import flash_attention
from repro.sparse import pack_bitmap, pack_block_sparse


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-3


@pytest.mark.parametrize("m,k,n,block", [
    (128, 128, 128, (128, 128)),
    (128, 256, 256, (128, 128)),
    (256, 128, 256, (64, 128)),
    (128, 384, 128, (128, 64)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sparsity", [0.5, 0.75, 0.95])
def test_bitmap_spmm_sweep(m, k, n, block, dtype, sparsity):
    r = np.random.default_rng(hash((m, k, n, sparsity)) % 2**32)
    w = r.standard_normal((k, n)).astype(np.float32)
    w *= r.random((k, n)) >= sparsity
    x = jnp.asarray(r.standard_normal((m, k)), dtype)
    bw = pack_bitmap(w.astype(dtype), block=block)
    out = bitmap_spmm(x, bw, interpret=True)
    expect = ref.bitmap_spmm_ref(x, bw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype) * np.sqrt(k), rtol=1e-2)


@pytest.mark.parametrize("m,k,n,block,p_zero", [
    (128, 256, 256, (128, 128), 0.5),
    (128, 512, 128, (128, 128), 0.75),
    (256, 256, 256, (64, 64), 0.3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse_sweep(m, k, n, block, p_zero, dtype):
    r = np.random.default_rng(hash((m, k, n, p_zero)) % 2**32)
    kt, nt = k // block[0], n // block[1]
    w = r.standard_normal((k, n)).astype(np.float32)
    mask = r.random((kt, nt)) >= p_zero
    w = (w.reshape(kt, block[0], nt, block[1])
         * mask[:, None, :, None]).reshape(k, n)
    bw = pack_block_sparse(jnp.asarray(w, dtype), block=block)
    x = jnp.asarray(r.standard_normal((m, k)), dtype)
    out = block_sparse_matmul(x, bw, interpret=True)
    expect = ref.block_sparse_matmul_ref(x, bw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype) * np.sqrt(k), rtol=1e-2)


@pytest.mark.parametrize("hq,hkv,s,d,window", [
    (4, 4, 128, 64, None),
    (4, 2, 256, 64, None),
    (8, 1, 128, 128, None),
    (4, 2, 256, 64, 64),
    (2, 2, 128, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(hq, hkv, s, d, window, dtype):
    r = np.random.default_rng(hash((hq, s, d, window or 0)) % 2**32)
    q = jnp.asarray(r.standard_normal((2, hq, s, d)), dtype)
    k = jnp.asarray(r.standard_normal((2, hkv, s, d)), dtype)
    v = jnp.asarray(r.standard_normal((2, hkv, s, d)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=64, bkv=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-3)


@pytest.mark.parametrize("m,bm", [(4, 4), (8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitmap_spmm_serving_head_shape(m, bm, dtype):
    """The serving engine's LM-head tile (BK != BN, tiny decode batch):
    interpret-mode kernel == dense reference."""
    r = np.random.default_rng(7)
    k, n = 64, 256
    w = r.standard_normal((k, n)).astype(np.float32)
    w *= r.random((k, n)) >= 0.6
    bw = pack_bitmap(w.astype(dtype), block=(64, 128))
    x = jnp.asarray(r.standard_normal((m, k)), dtype)
    out = bitmap_spmm(x, bw, bm=bm, interpret=True)
    expect = ref.bitmap_spmm_ref(x, bw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype) * np.sqrt(k), rtol=1e-2)


@pytest.mark.parametrize("m", [1, 4, 12])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitmap_spmm_small_m_decode_path(m, dtype):
    """Decode-shaped M (1..bm, not a multiple of 128): the small-M path
    rounds the row tile to the sublane multiple instead of padding 32x,
    and interpret-mode output equals the dense reference exactly in
    shape and numerically in value."""
    r = np.random.default_rng(m)
    k, n = 64, 256
    w = r.standard_normal((k, n)).astype(np.float32)
    w *= r.random((k, n)) >= 0.7
    bw = pack_bitmap(w.astype(dtype), block=(64, 128))
    x = jnp.asarray(r.standard_normal((m, k)), dtype)
    out = bitmap_spmm(x, bw, interpret=True)   # default bm=128 > m
    assert out.shape == (m, n)
    expect = ref.bitmap_spmm_ref(x, bw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype) * np.sqrt(k), rtol=1e-2)


def test_bitmap_spmm_m_not_multiple_of_bm():
    """M between bm and 2*bm that bm does not divide still works (pad to
    the next row-block, slice back)."""
    r = np.random.default_rng(0)
    k, n, m = 64, 128, 130
    w = r.standard_normal((k, n)).astype(np.float32)
    w *= r.random((k, n)) >= 0.5
    bw = pack_bitmap(w, block=(64, 64))
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    out = bitmap_spmm(x, bw, interpret=True)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.bitmap_spmm_ref(x, bw)),
                               atol=2e-3 * np.sqrt(k), rtol=1e-2)


def test_ops_bitmap_spmm_batched_activations():
    """The ops dispatcher accepts (..., K) activations (decode passes
    (B, 1, D)) on both impls."""
    from repro.kernels import ops
    r = np.random.default_rng(1)
    w = r.standard_normal((64, 128)).astype(np.float32)
    w *= r.random((64, 128)) >= 0.6
    bw = pack_bitmap(w, block=(64, 64))
    x = jnp.asarray(r.standard_normal((3, 1, 64)), jnp.float32)
    a = ops.bitmap_spmm(x, bw, impl="xla")
    b = ops.bitmap_spmm(x, bw, impl="pallas_interpret")
    assert a.shape == (3, 1, 128) and b.shape == (3, 1, 128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_hbm_traffic_model_shrinks_with_density():
    """Sparse HBM bytes < dense, and monotonically shrinking as the
    weight gets sparser (the paper's traffic-cut lever)."""
    r = np.random.default_rng(1)
    w0 = r.standard_normal((512, 512)).astype(np.float32)
    keep = r.random((512, 512))
    prev = None
    for sparsity in (0.5, 0.75, 0.9):
        bw = pack_bitmap(w0 * (keep >= sparsity), block=(128, 128))
        t = hbm_traffic_model((256, 512), bw)
        assert t["sparse_bytes"] < t["dense_bytes"]
        if prev is not None:
            assert t["sparse_bytes"] < prev
        prev = t["sparse_bytes"]


def test_hbm_traffic_model_reports_compression():
    r = np.random.default_rng(0)
    w = r.standard_normal((512, 512)).astype(np.float32)
    w *= r.random((512, 512)) >= 0.75
    bw = pack_bitmap(w, block=(128, 128))
    t = hbm_traffic_model((512, 512), bw)
    assert t["sparse_bytes"] < t["dense_bytes"]
    assert t["weight_compression"] > 2.0


def test_ops_dispatch_xla_path_matches():
    from repro.kernels import ops
    r = np.random.default_rng(0)
    w = r.standard_normal((128, 128)).astype(np.float32)
    w *= r.random((128, 128)) >= 0.6
    bw = pack_bitmap(w, block=(128, 128))
    x = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    a = ops.bitmap_spmm(x, bw, impl="xla")
    b = ops.bitmap_spmm(x, bw, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
