"""Deterministic fault injection + the step-level invariant auditor.

Three pillars:

* **audit is free of side effects** — with no faults injected,
  ``audit=True`` produces bit-identical tokens to the default engine
  across {contiguous, paged} × {reuse, preempt} × sparsity {0, 0.75}
  (the auditor reads, it never writes);
* **every fault recovers typed** — each injected fault kind (page-pool
  squeeze, forced preemption, prefix-eviction storm, NaN'd LM head,
  bit-flipped packed payload) terminates every request in a typed
  terminal state with *the clean run's exact tokens*, zero audit
  violations, and zero page leaks; corruption quarantines the offending
  tensor to its dense fallback with the reason in the manifest and
  ``report()["fallbacks"]``;
* **the contrast** — the same NaN fault with ``audit=False`` serves
  garbage (diverged tokens), which is exactly what the auditor exists
  to prevent.
"""
import warnings

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serve import FaultPlan, RequestState, ServeEngine

PROMPTS = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [4, 5, 6],
           [1, 2, 3, 4, 5, 6], [9, 8, 7, 6, 5]]


def _run(arch="olmo-1b", sparsity=0.5, max_new=6, **kw):
    cfg = get_smoke_config(arch)
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 64)
    eng = ServeEngine(cfg, seed=0, sparsity=sparsity, **kw)
    reqs = [eng.submit(p, max_new, arrival=float(i))
            for i, p in enumerate(PROMPTS)]
    rep = eng.run()
    return eng, rep, {r.rid: list(r.tokens) for r in reqs}


def _assert_clean(eng):
    """Zero page leaks after drain (paged engines)."""
    if eng.page_len:
        eng.kv.flush_prefix()
        eng.kv.audit()
        for pool in eng.kv.pools.values():
            assert not pool.ref and not pool.held


# ------------------------------------------- audit has no side effects ----


@pytest.mark.parametrize("sparsity", [0.0, 0.75])
@pytest.mark.parametrize("kw", [
    dict(),                                               # contiguous
    dict(paged=True, page_len=8),
    dict(paged=True, page_len=8, prefix_reuse=True),
    dict(paged=True, page_len=8, prefix_reuse=True,
         preempt=True, prefill_chunk=4),
], ids=["contig", "paged", "reuse", "reuse+preempt"])
def test_audit_mode_is_bit_identical(kw, sparsity):
    _, _, base = _run(sparsity=sparsity, **kw)
    eng, rep, toks = _run(sparsity=sparsity, audit=True, **kw)
    assert toks == base
    au = rep["lifecycle"]["audit"]
    assert au["enabled"] and au["steps_checked"] > 0
    _assert_clean(eng)


# ------------------------------------------------- per-fault recovery ----


def _plan(kind):
    p = FaultPlan(seed=11)
    if kind == "page_squeeze":
        return p.page_squeeze(step=4, pages=6, duration=5)
    if kind == "force_preempt":
        return p.force_preempt(step=4, count=1)
    if kind == "evict_storm":
        return p.evict_storm(step=5)
    if kind == "nan_logits":
        return p.nan_logits(step=4)
    if kind == "bitflip":
        return p.bitflip(step=5)
    raise AssertionError(kind)


@pytest.mark.parametrize("kind", ["page_squeeze", "force_preempt",
                                  "evict_storm", "nan_logits", "bitflip"])
def test_each_fault_recovers_to_clean_tokens(kind):
    kw = dict(paged=True, page_len=8, prefix_reuse=True, preempt=True,
              prefill_chunk=4)
    _, _, base = _run(**kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # quarantine warnings expected
        eng, rep, toks = _run(audit=True, faults=_plan(kind), **kw)
    fs = rep["lifecycle"]["faults"]
    assert fs["fired"] >= 1, f"{kind} never fired: {fs['log']}"
    assert toks == base, f"{kind}: tokens diverged from clean run"
    for r in eng.requests:
        assert r.state is RequestState.DONE and r.error is None
    _assert_clean(eng)
    if kind in ("nan_logits", "bitflip"):
        lc = rep["lifecycle"]
        assert lc["quarantined"], "corruption was not quarantined"
        for path, reason in lc["quarantined"].items():
            assert "quarantined" in reason
        # the quarantine is mirrored into the fallbacks section
        assert any(k == "head" or k.startswith("quarantine:")
                   for k in rep["fallbacks"])


def test_bitflip_quarantine_lands_in_manifest():
    kw = dict(paged=True, page_len=8, prefill_chunk=4)
    plan = FaultPlan(seed=2).bitflip(step=4, field="bitmap")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng, rep, _ = _run(audit=True, faults=plan, **kw)
    [(path, reason)] = list(rep["lifecycle"]["quarantined"].items())
    if path != "lm_head":
        entry = next(e for e in eng.packed.manifest
                     if e.path == path)
        assert not entry.packed and "quarantined" in entry.reason
        assert entry.layout == "dense" and entry.block is None
        # the leaf really dispatches dense now
        parts = path.split("/")
        assert eng.packed.blocks[parts[1]][parts[2]][parts[3]] is None


def test_combined_chaos_gemma_moe():
    """The whole seeded chaos schedule on a second arch (MoE): typed
    terminal states, clean-run tokens, zero violations, zero leaks."""
    kw = dict(arch="gemma3-4b", paged=True, page_len=8,
              prefix_reuse=True, preempt=True, prefill_chunk=4)
    _, _, base = _run(**kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng, rep, toks = _run(audit=True,
                              faults=FaultPlan.chaos(seed=3, horizon=24),
                              **kw)
    assert rep["lifecycle"]["faults"]["fired"] >= 3
    assert toks == base
    for r in eng.requests:
        assert r.terminal
    _assert_clean(eng)


def test_audit_off_nan_serves_garbage():
    """The contrast case: the same NaN'd LM head without the auditor
    silently diverges — detection + quarantine is what buys the
    bit-identical recovery above."""
    kw = dict(paged=True, page_len=8, prefill_chunk=4)
    _, _, base = _run(**kw)
    _, _, toks = _run(faults=FaultPlan(seed=7).nan_logits(step=3), **kw)
    assert toks != base, "NaN head should corrupt unaudited output"


def test_fault_plan_is_deterministic():
    p1 = FaultPlan.chaos(seed=9, horizon=30)
    p2 = FaultPlan.chaos(seed=9, horizon=30)
    assert [(f.step, f.kind) for f in p1.faults] == \
        [(f.step, f.kind) for f in p2.faults]
    kw = dict(paged=True, page_len=8, prefix_reuse=True, preempt=True,
              prefill_chunk=4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        _, r1, t1 = _run(audit=True, faults=p1, **kw)
        _, r2, t2 = _run(audit=True, faults=p2, **kw)
    assert t1 == t2
    assert r1["lifecycle"]["faults"]["log"] == \
        r2["lifecycle"]["faults"]["log"]
