"""Deterministic offline stand-in for the ``hypothesis`` subset used here.

This container cannot pip-install anything, so when the real library is
missing ``conftest.py`` installs this module as ``hypothesis`` (and
``hypothesis.strategies``).  It implements exactly the API surface the
property-test modules use:

* ``@settings(max_examples=N, deadline=None)``
* ``@given(strategy, ...)`` — runs the test body ``max_examples`` times
  with draws from a per-test seeded ``numpy`` RNG (seed = CRC32 of the
  test's qualified name, so example sequences are stable across runs
  and machines);
* ``strategies.integers / floats / booleans / lists / tuples /
  sampled_from``.

Unlike real hypothesis there is no shrinking and no adaptive search —
failures report the drawn example verbatim.  The point is that the
paper-fidelity property tests *run* offline; with real hypothesis
installed the shim is never imported.
"""
from __future__ import annotations

import sys
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 100

__version__ = "0.0-shim"


class _Strategy:
    """A strategy is just a draw function over a numpy Generator."""

    def __init__(self, draw, repr_=""):
        self._draw = draw
        self._repr = repr_ or "strategy()"

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):
        return self._repr


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value, endpoint=True)),
        f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)),
                     f"floats({min_value}, {max_value})")


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))],
                     f"sampled_from({elements!r})")


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size, endpoint=True))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw, f"lists({elements!r}, {min_size}, {max_size})")


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elements),
                     f"tuples({', '.join(repr(e) for e in elements)})")


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_kw):
    """Store the example budget on the (already ``given``-wrapped) test."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies, **kw_strategies):
    """Run the test ``max_examples`` times with deterministic draws.

    The wrapper takes *no* parameters so pytest does not try to resolve
    the strategy-bound argument names as fixtures (real hypothesis
    rewrites the signature the same way).
    """
    def deco(fn):
        def runner():
            n = getattr(runner, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                args = tuple(s.example(rng) for s in strategies)
                kwargs = {k: s.example(rng)
                          for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as e:  # report the failing example
                    raise AssertionError(
                        f"{fn.__qualname__} failed on drawn example "
                        f"args={args!r} kwargs={kwargs!r}: {e}") from e

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis_shim = True
        return runner
    return deco


def assume(condition) -> bool:
    """Best-effort: a failed assumption just skips the rest via assert."""
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


class _StrategiesModule:
    """Stands in for the ``hypothesis.strategies`` module."""

    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
    sampled_from = staticmethod(sampled_from)


strategies = _StrategiesModule()


def install():
    """Register this module as ``hypothesis`` in ``sys.modules``."""
    mod = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", mod)
    sys.modules.setdefault("hypothesis.strategies", strategies)
