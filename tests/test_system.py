"""End-to-end behaviour of the whole system (drivers + public API)."""
import numpy as np
import jax.numpy as jnp

from repro.core import run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.launch.serve import serve
from repro.launch.train import train


def test_end_to_end_sparse_accelerator_study():
    """Paper pipeline in one call: prune -> simulate -> metrics coherent."""
    r = np.random.default_rng(0)
    x = random_sparse((128, 256), 0.45, r)
    w = prune_global_l1(r.standard_normal((96, 256)).astype(np.float32),
                        0.75)
    rep = run_gemm(x, w, compute_values=True)
    np.testing.assert_allclose(rep.outputs, x @ w.T, atol=1e-4)
    s = rep.summary()
    assert 0.1 < s["mapm"] < 1.0
    assert s["speedup_vs_dense"] > 1.5
    assert s["utilization"] > 0.3


def test_end_to_end_sparse_training_driver():
    res = train("granite-moe-3b-a800m", smoke=True, steps=10, batch=4,
                seq=32, sparsity=0.5, lr=1e-3)
    assert np.isfinite(res["final_loss"])
    from repro.sparse.pruning import sparsity_of
    assert sparsity_of(res["params"]) > 0.4  # masks held through training


def test_end_to_end_serving_driver():
    res = serve("rwkv6-3b", smoke=True, batch=2, steps=6, sparsity=0.5)
    assert res["tokens"].shape == (2, 6)
    assert res["tok_per_s"] > 0
