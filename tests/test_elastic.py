"""Elastic restart: a checkpoint written under one mesh restores onto a
different mesh (device loss / topology change), with identical values."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch import sharding as shd
    from repro.models import init_params
    from repro.train import checkpoint as ckpt

    cfg = get_smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    d = tempfile.mkdtemp()
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    pa = jax.device_put(params, shd.named(mesh_a, shd.param_specs(cfg,
                                                                  mesh_a)))
    ckpt.save(d, 1, {"params": pa})

    # "lose" half the fleet: restore onto a 2x2 mesh
    mesh_b = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    shard_b = shd.named(mesh_b, shd.param_specs(cfg, mesh_b))
    back = ckpt.restore(d, 1, {"params": params},
                        shardings={"params": shard_b})
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params,
        back["params"])))
    ndev = len({dv for leaf in jax.tree.leaves(back["params"])
                for dv in leaf.devices()})
    print(json.dumps({"diff": diff, "ndev": ndev}))
""")


def test_checkpoint_restores_onto_smaller_mesh():
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["diff"] == 0.0
    assert res["ndev"] == 4
