"""Gradient accumulation: exact numerical parity with the fused step."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.launch.steps import build_train_step
from repro.models import init_params
from repro.train import optimizer as opt_lib


@pytest.mark.parametrize("accum", [2, 4])
def test_accum_matches_single_pass(accum, rng):
    cfg = get_smoke_config("olmo-1b")
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                               jnp.int32),
    }
    s1 = build_train_step(cfg, ocfg, accum_steps=1)
    sa = build_train_step(cfg, ocfg, accum_steps=accum)
    p1, o1, m1 = jax.jit(s1)(params, opt_state, batch)
    pa, oa, ma = jax.jit(sa)(params, opt_state, batch)
    assert float(m1["loss"]) == pytest.approx(float(ma["loss"]), abs=1e-5)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, pa)))
    assert diff < 1e-5, diff
