"""MAPM analytics (paper §I): the dense example + baseline models."""
import numpy as np

from repro.core.accelerator import run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.core.mapm import (SCNN_PAPER_MAPM, SPARTEN_PAPER_MAPM,
                             dense_output_stationary, reduction_vs_sparten,
                             scnn, sparten)


def test_paper_dense_4x4_example():
    """The paper's worked example: 4×4×4 dense on a 4×4 output-stationary
    array -> 32 reads + 16 writes / 64 MACs = 0.75 byte/MAC."""
    c = dense_output_stationary(4, 4, 4, tile=4)
    assert c.macs == 64
    assert c.sram_bytes == 48
    assert abs(c.mapm - 0.75) < 1e-9


def test_no_reuse_is_4_bytes_per_mac():
    """Paper: without reuse MAPM would be 4 byte/MAC (2 reads + 1 psum read
    + 1 write)."""
    assert 2 + 1 + 1 == 4


def test_sparten_scnn_models_near_paper():
    """Our first-principles models land near the paper's measured numbers
    (SparTen 2.09, SCNN 2.03 byte/MAC)."""
    r = np.random.default_rng(0)
    x = random_sparse((256, 512), 0.45, r)
    w = prune_global_l1(r.standard_normal((256, 512)).astype(np.float32),
                        0.75)
    nnz_macs = int(((x != 0).astype(int) @ (w != 0).astype(int).T).sum())
    sp = sparten(nnz_macs, 256 * 256)
    sc = scnn(nnz_macs, int((x != 0).sum()), int((w != 0).sum()))
    assert abs(sp.mapm - SPARTEN_PAPER_MAPM) < 0.15
    assert abs(sc.mapm - SCNN_PAPER_MAPM) < 0.15


def test_our_design_beats_baselines_by_wide_margin():
    r = np.random.default_rng(1)
    x = random_sparse((64, 256), 0.45, r)
    w = prune_global_l1(r.standard_normal((64, 256)).astype(np.float32),
                        0.75)
    rep = run_gemm(x, w)
    assert rep.mapm < 0.6                      # paper: 0.29 avg over layers
    assert rep.sram_reduction_vs_sparten > 0.7  # paper: 86 %
    assert rep.mapm < rep.sparten_counts.mapm / 3
    assert rep.mapm < rep.scnn_counts.mapm / 3


def test_reduction_headline():
    assert abs(reduction_vs_sparten(0.29) - 0.861) < 0.005
