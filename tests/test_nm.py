"""N:M structured sparsity: pack/unpack, pruning structure, kernel sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.nm_spmm import nm_spmm
from repro.sparse.nm import NmWeight, pack_nm, prune_nm, unpack_nm


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([(1, 4), (2, 4), (2, 8)]))
def test_prune_nm_structure(seed, nm):
    n, m = nm
    r = np.random.default_rng(seed)
    w = r.standard_normal((64, 32)).astype(np.float32)
    p = prune_nm(w, n, m)
    nnz_per_group = (p.reshape(64 // m, m, 32) != 0).sum(1)
    assert (nnz_per_group <= n).all()
    # kept entries are the group-wise largest magnitudes
    groups = np.abs(w.reshape(64 // m, m, 32))
    kept = np.abs(p.reshape(64 // m, m, 32))
    for g in range(64 // m):
        for c in range(32):
            thresh = np.sort(groups[g, :, c])[-n]
            assert (kept[g, :, c][kept[g, :, c] > 0] >= thresh - 1e-6).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([(1, 4), (2, 4)]))
def test_pack_unpack_roundtrip(seed, nm):
    n, m = nm
    r = np.random.default_rng(seed)
    w = prune_nm(r.standard_normal((256, 128)).astype(np.float32), n, m)
    nw = pack_nm(w, n, m, block=(128, 128))
    np.testing.assert_array_equal(np.asarray(unpack_nm(nw)), w)
    itemsize = 4  # f32 values in this test; bf16 gives (2K)/(K/m*n*3)
    expect_comp = (itemsize * 256 * 128) / (
        (256 // m * n * 128) * (itemsize + 1))
    assert nw.compression == pytest.approx(expect_comp, rel=0.01)


@pytest.mark.parametrize("mk,nm,block", [
    ((128, 256, 128), (1, 4), (128, 128)),
    ((128, 256, 256), (2, 4), (128, 128)),
    ((256, 128, 128), (1, 4), (64, 64)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nm_spmm_kernel_sweep(mk, nm, block, dtype):
    m_rows, k, n_cols = mk
    n, m = nm
    r = np.random.default_rng(hash((mk, nm)) % 2**32)
    w = prune_nm(r.standard_normal((k, n_cols)).astype(np.float32), n, m)
    nw = pack_nm(w.astype(dtype), n, m, block=block)
    x = jnp.asarray(r.standard_normal((m_rows, k)), dtype)
    out = nm_spmm(x, nw, interpret=True)
    expect = jnp.dot(x, jnp.asarray(w, dtype),
                     preferred_element_type=jnp.float32).astype(dtype)
    atol = (2e-2 if dtype == jnp.bfloat16 else 2e-3) * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=1e-2)
