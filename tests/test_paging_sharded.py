"""Sharded page-pool allocator properties (offline hypothesis shim).

The data-axis sharded ``PagedKVCache`` partitions slots and page-id
ranges into per-shard groups (each with its own trash page).  Under
random admit / ensure / retire / prefix-adopt / confiscate / evict
sequences the allocator must keep, per shard:

* **conservation** — every data page id is in exactly one of
  {free, referenced, fault-held}, always summing to ``shard_pages``;
* **no cross-shard aliasing** — a slot only ever maps pages from its
  own shard's range, prefix blocks stay in the shard that wrote them;
* **refcount exactness** — ``audit()`` (which checks table mappings +
  prefix holds against the recorded refcounts) passes at every step.

Plus the end-to-end contracts: chaos faults (page squeeze, forced
preemption, eviction storm) on a sharded engine decode bit-identically
to the clean sharded run, and a slot count the data axis doesn't divide
degrades to a typed ``kv_shard`` fallback — never a crash, and never a
reason that blames ``model_parallel`` (those fallbacks are retired).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.serve import PagedKVCache

TIMEOUT = 600


def _per_shard_invariants(kv):
    """Independent re-derivation of the sharded conservation + aliasing
    invariants (not just a re-run of ``kv.audit()``)."""
    for b, pool in kv.pools.items():
        span = pool.shard_pages + 1          # shard range incl. trash
        for d in range(pool.shards):
            ids = set(range(d * span + 1, (d + 1) * span))
            free_d = {pg for pg in pool.free if pg in ids}
            ref_d = {pg for pg in pool.ref if pg in ids}
            held_d = {pg for pg in pool.held if pg in ids}
            assert not (free_d & ref_d), f"{b}: free and referenced"
            assert not (free_d & held_d), f"{b}: free and held"
            assert not (ref_d & held_d), f"{b}: referenced and held"
            assert free_d | ref_d | held_d == ids, \
                f"{b}: shard {d} conservation broken"
        for s in range(kv.num_slots):
            row = pool.table[s]
            d = kv.slot_shard(s)
            for pg in (int(p) for p in row[row != 0]):
                assert pg // span == d and pg % span != 0, \
                    f"{b}: slot {s} (shard {d}) maps foreign page {pg}"
        for e in kv.prefix.values():
            pg = e.pages[b]
            assert pg // span == e.shard, \
                f"{b}: prefix block crossed into shard {pg // span}"


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4]),
       st.lists(st.integers(1, 30), min_size=1, max_size=12),
       st.integers(8, 64))
def test_sharded_allocator_invariants_under_random_load(shards, needs,
                                                        pool_tokens):
    """Random request sizes and pool budgets against a sharded pool,
    full admit/ensure/retire lifecycles; admission targets whichever
    free slot's shard has room (per-shard reserve), like the engine."""
    cfg = get_smoke_config("gemma3-4b")    # windowed + global blocks
    kv = PagedKVCache(cfg, num_slots=4, max_len=32, page_len=8,
                      pool_tokens=pool_tokens, shards=shards)
    needs = [n for n in needs if kv.possible(n)]
    active = {}                            # slot -> [next position, need]
    free_slots = [0, 1, 2, 3]
    guard = 0
    while (needs or active) and guard < 600:
        guard += 1
        for slot, (pos, need) in list(active.items()):
            if pos >= need:
                kv.retire(slot)
                free_slots.append(slot)
                del active[slot]
        while needs and free_slots:
            need = needs[0]
            slot = next((s for s in free_slots
                         if kv.reserve(need, slot=s)), None)
            if slot is None:               # every free shard is full
                break
            needs.pop(0)
            free_slots.remove(slot)
            kv.admit(slot, need)
            active[slot] = [0, need]
        for slot in list(active):
            pos, need = active[slot]
            kv.ensure(slot, pos)
            active[slot][0] = pos + 1
        kv.audit()
        _per_shard_invariants(kv)
    assert not needs and not active, "sharded allocator stalled"
    for pool in kv.pools.values():
        assert pool.in_use == 0 and pool.committed == 0
        assert pool.committed_by == [0] * pool.shards
        assert len(pool.free) == pool.pool_pages


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([2, 4]),
       st.lists(st.tuples(st.integers(0, 3),    # slot
                          st.integers(0, 2),    # prompt family
                          st.integers(1, 15),   # suffix length
                          st.integers(0, 3)),   # chaos action
               min_size=1, max_size=10))
def test_sharded_prefix_cow_keeps_shards_disjoint(shards, reqs):
    """Shard-salted prefix chains: identical prompts admitted into
    different shards must cache and adopt independently — refcounts
    exact, no block ever maps a foreign shard's pages — with fault
    confiscation/restore and targeted eviction interleaved."""
    cfg = get_smoke_config("olmo-1b")
    kv = PagedKVCache(cfg, num_slots=4, max_len=32, page_len=8,
                      shards=shards)
    for slot, fam, extra, chaos in reqs:
        tokens = ([fam * 7 + 1 + (j % 5) for j in range(16)]
                  + [fam + 2 + j for j in range(extra)])[:31]
        need = len(tokens) + 1
        if kv._commit[slot]:
            kv.retire(slot)
        if not kv.fits(need, slot=slot):   # same-shard peer holds pages
            for s in range(kv.num_slots):
                if s != slot and kv._commit[s] \
                        and kv.slot_shard(s) == kv.slot_shard(slot):
                    kv.retire(s)
        if not kv.fits(need, slot=slot):   # fault-held pages squeeze it
            kv.restore_held()
        matched, blocks = kv.match_prefix(tokens, slot=slot)
        if not kv.reserve(need, slot=slot):
            continue                       # shard genuinely full: queue
        adopted = kv.admit(slot, need, prefix=blocks)
        assert adopted == matched
        kv.ensure_range(slot, adopted, len(tokens))
        kv.register_prefix(slot, tokens, upto=len(tokens))
        if chaos == 1:
            kv.confiscate(1)
        elif chaos == 2:
            kv.restore_held()
        elif chaos == 3:
            kv.evict_one(shard=kv.slot_shard(slot))
        kv.audit()
        _per_shard_invariants(kv)
    for s in range(kv.num_slots):
        if kv._commit[s]:
            kv.retire(s)
    kv.restore_held()
    kv.flush_prefix()
    kv.audit()
    for pool in kv.pools.values():
        assert not pool.ref and not pool.held, "pages leaked"
        span = pool.shard_pages + 1
        assert sorted(pool.free) == [d * span + pg
                                     for d in range(pool.shards)
                                     for pg in range(1, span)]


# -------------------- chaos + fallback contract on the sharded engine ------


_WORKER = textwrap.dedent("""
    import json, os, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.configs import get_smoke_config
    from repro.serve import FaultPlan, RequestState, ServeEngine

    PROMPTS = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [4, 5, 6],
               [1, 2, 3, 4, 5, 6], [9, 8, 7, 6, 5],
               [1, 2, 3, 4, 5, 6, 7, 8], [2, 4, 6, 8]]


    def run(faults=None):
        cfg = get_smoke_config("olmo-1b")
        eng = ServeEngine(cfg, num_slots=8, max_len=48, sparsity=0.5,
                          model_parallel=2, seed=0, paged=True,
                          page_len=8, prefix_reuse=True, preempt=True,
                          prefill_chunk=4, audit=True, faults=faults)
        reqs = [eng.submit(p, 6, arrival=float(i),
                           temperature=(0.8 if i % 2 else 0.0),
                           seed=40 + i, top_k=(8 if i % 2 else None))
                for i, p in enumerate(PROMPTS)]
        rep = eng.run()
        eng.kv.flush_prefix()
        eng.kv.audit()
        leaks = sum(len(p.ref) + len(p.held)
                    for p in eng.kv.pools.values())
        return {
            "kv_shards": int(eng.kv.shards),
            "tokens": {str(r.rid): [int(t) for t in r.tokens]
                       for r in reqs},
            "states": [r.state is RequestState.DONE and r.error is None
                       for r in reqs],
            "fired": int(rep["lifecycle"]["faults"]["fired"]
                         if faults is not None else 0),
            "leaks": int(leaks),
            "fallbacks": {k: str(v) for k, v in rep["fallbacks"].items()},
        }


    plan = (FaultPlan(seed=11).page_squeeze(step=4, pages=6, duration=5)
            .force_preempt(step=6, count=1).evict_storm(step=9))
    clean = run()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        chaos = run(faults=plan)

    # indivisible slot count: 5 slots over a 2-extent data axis
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cfg = get_smoke_config("olmo-1b")
        eng = ServeEngine(cfg, num_slots=5, max_len=32, sparsity=0.5,
                          model_parallel=4, seed=0, paged=True,
                          page_len=8)
        req = eng.submit([3, 1, 4, 1, 5], 4)
        rep = eng.run()
    indiv = {
        "kv_shards": int(eng.kv.shards),
        "tokens": [int(t) for t in req.tokens],
        "fallbacks": {k: str(v) for k, v in rep["fallbacks"].items()},
    }
    print(json.dumps({"clean": clean, "chaos": chaos, "indiv": indiv}))
""")

_CACHE = {}


def _worker():
    if "out" not in _CACHE:
        env = {**os.environ, "PYTHONPATH": "src"}
        proc = subprocess.run([sys.executable, "-c", _WORKER],
                              capture_output=True, text=True,
                              timeout=TIMEOUT, env=env,
                              cwd=os.path.dirname(os.path.dirname(
                                  os.path.abspath(__file__))))
        assert proc.returncode == 0, \
            f"sharded chaos worker failed:\n{proc.stderr[-3000:]}"
        _CACHE["out"] = json.loads(proc.stdout.strip().splitlines()[-1])
    return _CACHE["out"]


def test_chaos_on_sharded_engine_matches_clean_run():
    out = _worker()
    clean, chaos = out["clean"], out["chaos"]
    assert clean["kv_shards"] == 4          # 8 devices / mp=2
    assert chaos["fired"] >= 3, "not every fault fired"
    assert chaos["tokens"] == clean["tokens"], \
        "faulted sharded run diverged from clean sharded run"
    assert all(chaos["states"]) and all(clean["states"])
    assert chaos["leaks"] == 0 and clean["leaks"] == 0


def test_kv_shard_fallback_is_typed_and_serving_continues():
    out = _worker()
    indiv = out["indiv"]
    assert indiv["kv_shards"] == 1          # degraded, not crashed
    assert len(indiv["tokens"]) == 4        # still serving
    assert "kv_shard" in indiv["fallbacks"], indiv["fallbacks"]
    assert indiv["fallbacks"]["kv_shard"].startswith("shard:")
    for run in (out["clean"], out["chaos"], indiv):
        for reason in run["fallbacks"].values():
            assert "model_parallel" not in reason, reason
