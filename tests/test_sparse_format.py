"""Bitmap / block-sparse weight containers: roundtrip + budget + traffic."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse import (pack_bitmap, pack_block_sparse, unpack_bitmap,
                          unpack_block_sparse)
from repro.sparse.pruning import per_tensor_prune, sparsity_of
import jax.numpy as jnp


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.1, 0.95))
def test_bitmap_roundtrip(seed, sparsity):
    r = np.random.default_rng(seed)
    w = r.standard_normal((128, 256)).astype(np.float32)
    w *= r.random((128, 256)) >= sparsity
    bw = pack_bitmap(w, block=(64, 64))
    np.testing.assert_array_equal(np.asarray(unpack_bitmap(bw)), w)
    # compression beats dense once sparsity clears the bitmap overhead
    if sparsity > 0.3:
        assert bw.compression > 1.0


def test_bitmap_budget_reprune():
    """Tiles denser than the budget are re-pruned to top magnitudes."""
    r = np.random.default_rng(0)
    w = r.standard_normal((64, 64)).astype(np.float32)  # fully dense
    bw = pack_bitmap(w, block=(64, 64), density_budget=0.25)
    dense = np.asarray(unpack_bitmap(bw))
    kept = dense != 0
    assert kept.sum() <= int(np.ceil(0.25 * 64 * 64))
    # kept entries are exactly the largest |w|
    thresh = np.abs(dense[kept]).min()
    assert (np.abs(w[~kept]) <= thresh + 1e-6).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.2, 0.9))
def test_block_sparse_roundtrip(seed, p_zero):
    r = np.random.default_rng(seed)
    kt, nt, bk, bn = 4, 3, 32, 32
    w = r.standard_normal((kt * bk, nt * bn)).astype(np.float32)
    mask = r.random((kt, nt)) >= p_zero
    w = (w.reshape(kt, bk, nt, bn)
         * mask[:, None, :, None]).reshape(kt * bk, nt * bn)
    bw = pack_block_sparse(w, block=(bk, bn))
    np.testing.assert_array_equal(np.asarray(unpack_block_sparse(bw)), w)
    assert abs(bw.density - mask.mean()) < 1e-9


def test_per_tensor_prune_exact():
    r = np.random.default_rng(1)
    w = jnp.asarray(r.standard_normal((64, 64)), jnp.float32)
    pruned = per_tensor_prune(w, 0.75)
    frac = float((pruned == 0).mean())
    assert abs(frac - 0.75) < 0.01
    assert sparsity_of({"w": pruned}) == frac
