"""Sharded packed serving over a real (fake-device) mesh.

Each scenario runs the full engine twice in subprocesses — once on a
single device (the oracle) and once on an 8-fake-device host mesh
(``--xla_force_host_platform_device_count=8``) at one or more
``model_parallel`` settings — and asserts:

* **token bit-identity**: every request's sampled tokens match the
  single-device oracle exactly (greedy and temperature-sampled alike —
  the sampled requests are what make the comparison discriminating);
* **zero unexpected fallbacks**: the ``report()["fallbacks"]`` key set
  matches the oracle's (granite's vocab=255 head falls back to dense on
  *every* topology), no reason mentions ``model_parallel`` (the old
  mp>1 stream/paging fallbacks are retired), and mp>1 runs shard every
  eligible tensor (empty ``shard_fallbacks``);
* **per-device weight HBM ~ 1/mp**: summed over the sharded manifest
  entries, device bytes are the total floor-divided per tensor, and the
  traffic ledger's device columns equal the engine's by construction.

Sharding that cannot apply (indivisible dims, vocab the shard count
does not divide) must degrade to a typed per-tensor reason — never a
crash — which the non-subprocess tests at the bottom pin directly.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

TIMEOUT = 600

_WORKER = textwrap.dedent("""
    import json, os, warnings
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%NDEV%")
    from repro.configs import get_smoke_config
    from repro.serve.engine import ServeEngine

    SPEC = json.loads('''%SPEC%''')


    def run(mp):
        cfg = get_smoke_config(SPEC["arch"])
        kw = {}
        if SPEC["paged"]:
            kw.update(paged=True, page_len=8, prefix_reuse=True,
                      preempt=True)
        if SPEC["prefill_chunk"]:
            kw["prefill_chunk"] = SPEC["prefill_chunk"]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # granite head fallback
            eng = ServeEngine(cfg, num_slots=SPEC["num_slots"],
                              max_len=48, sparsity=SPEC["sparsity"],
                              model_parallel=mp, seed=0, **kw)
        prompts = [[1 + (i * 7 + j) % 250 for j in range(5 + i % 4)]
                   for i in range(6)]
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=6, arrival=float(i // 2),
                       temperature=(0.8 if i % 2 else 0.0),
                       seed=100 + i, top_k=(8 if i % 2 else None))
        rep = eng.run()
        ws = rep["weight_stream"]
        tw = rep["traffic"]["weight"]
        # ledger <-> engine identity, device columns included
        assert tw["sparse_bytes_per_step"] == ws["sparse_bytes_per_step"]
        assert tw["device_sparse_bytes_per_step"] == \
            ws["device_sparse_bytes_per_step"], (tw, ws)
        if SPEC["paged"]:
            eng.kv.audit()
        sh_dev = sh_tot = nsh = 0
        if eng.packed is not None:
            for e in eng.packed.manifest:
                if e.shard is not None:
                    nsh += 1
                    sh_tot += int(e.sparse_bytes)
                    sh_dev += int(e.sparse_bytes) // e.shard[1]
        return {
            "mesh": {k: int(v) for k, v in eng.mesh.shape.items()},
            "spmd": bool(eng._spmd),
            "tokens": {str(r.rid): [int(t) for t in r.tokens]
                       for r in eng.requests},
            "fallbacks": {k: str(v) for k, v in rep["fallbacks"].items()},
            "shard_fallbacks": dict(ws["shard_fallbacks"]),
            "shards": int(ws["shards"]),
            "kv_shards": int(eng.kv.shards) if SPEC["paged"] else 1,
            "dev_sparse": int(ws["device_sparse_bytes_per_step"]),
            "tot_sparse": int(ws["sparse_bytes_per_step"]),
            "sharded_entries": int(nsh),
            "packed_dev": int(sh_dev),
            "packed_tot": int(sh_tot),
        }


    print(json.dumps({str(mp): run(mp) for mp in SPEC["mps"]}))
""")

# Pairwise coverage of the full matrix: both archs, mp in {1, 2, 4},
# sparsity in {0, 0.75}, contiguous vs paged KV, legacy decode vs
# chunked prefill.  Paged scenarios use num_slots=8 so every data-axis
# extent (8/mp) divides the slot count and the KV pool actually shards.
SCENARIOS = {
    "olmo-sparse-paged-prefill": dict(
        arch="olmo-1b", sparsity=0.75, paged=True, prefill_chunk=8,
        num_slots=8, mps=[1, 2, 4]),
    "olmo-dense-contig-decode": dict(
        arch="olmo-1b", sparsity=0.0, paged=False, prefill_chunk=0,
        num_slots=4, mps=[2]),
    "granite-sparse-contig-decode": dict(
        arch="granite-moe-3b-a800m", sparsity=0.75, paged=False,
        prefill_chunk=0, num_slots=4, mps=[4]),
    "granite-dense-paged-prefill": dict(
        arch="granite-moe-3b-a800m", sparsity=0.0, paged=True,
        prefill_chunk=8, num_slots=8, mps=[2]),
}

_CACHE = {}


def _worker(name, ndev, mps):
    key = (name, ndev, tuple(mps))
    if key in _CACHE:
        return _CACHE[key]
    spec = dict(SCENARIOS[name], mps=list(mps))
    script = (_WORKER.replace("%NDEV%", str(ndev))
              .replace("%SPEC%", json.dumps(spec)))
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True,
                          timeout=TIMEOUT, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, \
        f"{name} (ndev={ndev}) failed:\n{proc.stderr[-3000:]}"
    _CACHE[key] = json.loads(proc.stdout.strip().splitlines()[-1])
    return _CACHE[key]


@pytest.mark.parametrize("name", list(SCENARIOS))
def test_sharded_serving_matches_single_device(name):
    spec = SCENARIOS[name]
    oracle = _worker(name, 1, [1])["1"]
    assert oracle["spmd"] is False
    assert oracle["shards"] == 1

    runs = _worker(name, 8, spec["mps"])
    for mp_s, r in runs.items():
        mp = int(mp_s)
        ctx = f"{name} mp={mp}"
        assert r["spmd"] is True, ctx
        assert r["mesh"] == {"data": 8 // mp, "model": mp}, ctx

        # the whole point: tokens are bit-identical to one device
        assert r["tokens"] == oracle["tokens"], ctx

        # no unexpected fallbacks, and none blamed on model_parallel
        assert set(r["fallbacks"]) == set(oracle["fallbacks"]), ctx
        for reason in r["fallbacks"].values():
            assert "model_parallel" not in reason, (ctx, reason)

        assert r["shards"] == mp, ctx
        if spec["paged"]:
            # KV pools shard over the data axis (8 // mp extents)
            assert r["kv_shards"] == 8 // mp, ctx

        if mp > 1:
            # every TP-eligible tensor actually sharded on these shapes
            assert r["shard_fallbacks"] == {}, ctx
            assert r["sharded_entries"] > 0, ctx
            # per-device packed bytes == total / mp, floor-div per tensor
            assert r["packed_dev"] * mp <= r["packed_tot"], ctx
            assert (r["packed_tot"] - r["packed_dev"] * mp
                    < mp * r["sharded_entries"]), ctx
            assert r["dev_sparse"] < r["tot_sparse"], ctx
        else:
            assert r["dev_sparse"] == r["tot_sparse"], ctx


# ------------------- typed degradation, no crash (single device) ----------


def test_indivisible_dims_record_typed_shard_reasons():
    """A shard count that divides nothing still packs — every eligible
    tensor keeps its unsharded tile and carries a typed shard reason."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serve import pack_model

    cfg = get_smoke_config("olmo-1b")        # d_model=64: 3 divides nothing
    params = init_params(jax.random.PRNGKey(0), cfg)
    pm = pack_model(params, shards=3)
    sharded = [e for e in pm.manifest if e.shard is not None]
    reasons = {e.path: e.shard_reason for e in pm.manifest
               if e.shard_reason}
    assert sharded == [], sharded
    assert reasons, "expected typed per-tensor shard fallbacks"
    for path, reason in reasons.items():
        assert reason.startswith("shard:"), (path, reason)
        assert "replicated" in reason, (path, reason)
    # packing itself is unaffected: the entries still packed
    assert any(e.packed for e in pm.manifest)
    rep = pm.stream_report()
    assert rep["shards"] == 3
    assert set(rep["shard_fallbacks"]) == set(reasons)
    # nothing sharded -> device bytes degenerate to the totals
    assert rep["device_sparse_bytes_per_step"] == rep["sparse_bytes_per_step"]


def test_indivisible_vocab_keeps_head_replicated():
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import init_params
    from repro.serve.engine import pack_lm_head

    cfg = get_smoke_config("olmo-1b")        # vocab=256: 3 doesn't divide
    params = init_params(jax.random.PRNGKey(1), cfg)
    bw = pack_lm_head(params, cfg, sparsity=0.5, shards=3)
    assert bw is not None and bw.shard is None
    sharded = pack_lm_head(params, cfg, sparsity=0.5, shards=4)
    assert sharded is not None and sharded.shard == ("col", 4)
