"""Whole-accelerator model: tiled GEMM correctness, sampling, energy."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.accelerator import AcceleratorConfig, run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.core.energy import energy_from_stats, power_watts, tops_per_watt


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.0, 0.8), st.floats(0.3, 0.9))
def test_tiled_gemm_exact(seed, si, sw):
    r = np.random.default_rng(seed)
    x = random_sparse((48, 80), si, r)        # non-multiple of 16 on K
    w = random_sparse((33, 80), sw, r)        # ragged N tile
    rep = run_gemm(x, w, compute_values=True)
    np.testing.assert_allclose(rep.outputs, x @ w.T, atol=1e-4)


def test_k_chunking_matches_single_pass():
    r = np.random.default_rng(2)
    x = random_sparse((32, 256), 0.4, r)
    w = random_sparse((32, 256), 0.7, r)
    rep1 = run_gemm(x, w, AcceleratorConfig(k_buffer=4096),
                    compute_values=True)
    rep2 = run_gemm(x, w, AcceleratorConfig(k_buffer=64),
                    compute_values=True)
    np.testing.assert_allclose(rep1.outputs, rep2.outputs, atol=1e-4)
    assert rep1.stats.macs == rep2.stats.macs
    # outputs hit SRAM once regardless of K chunking
    assert rep1.stats.output_bytes == rep2.stats.output_bytes


def test_row_subsampling_unbiased():
    r = np.random.default_rng(3)
    x = random_sparse((512, 128), 0.45, r)
    w = prune_global_l1(r.standard_normal((64, 128)).astype(np.float32), 0.75)
    full = run_gemm(x, w)
    sub = run_gemm(x, w, max_row_tiles=8)
    assert sub.sampled_fraction == 8 / 32
    assert abs(sub.mapm - full.mapm) / full.mapm < 0.15
    assert abs(sub.utilization - full.utilization) / full.utilization < 0.15


def test_energy_accounting():
    r = np.random.default_rng(4)
    x = random_sparse((64, 128), 0.4, r)
    w = random_sparse((48, 128), 0.75, r)
    rep = run_gemm(x, w)
    e = energy_from_stats(rep.stats)
    bd = rep.energy.breakdown()
    assert abs(sum(bd.values()) - 1.0) < 1e-9
    assert e.total_j > 0
    assert tops_per_watt(rep.stats.macs, e.total_j) > 0
    assert power_watts(e.total_j, rep.stats.cycles) > 0
    # paper Fig. 8: EIM overhead is less than half of MAC power
    assert e.eim_j < 0.5 * e.mac_j


def test_energy_ratio_vs_sparten_dataflow():
    """The core claim: cutting SRAM traffic ~7x cuts energy/op materially
    (paper: 2.5x power-efficiency gain)."""
    from repro.core.energy import energy_dataflow
    r = np.random.default_rng(5)
    x = random_sparse((128, 512), 0.45, r)
    w = prune_global_l1(r.standard_normal((128, 512)).astype(np.float32),
                        0.75)
    rep = run_gemm(x, w)
    ours = energy_from_stats(rep.stats).total_j
    # SparTen-style: same MACs, 2.09 B/MAC, ~same cycle count at util~0.5
    sp_bytes = 2.09 * rep.stats.macs
    sp = energy_dataflow(rep.stats.macs, sp_bytes, rep.stats.cycles)
    assert sp / ours > 1.5, (sp, ours)
