"""SIDR cycle simulator (paper Algorithm 1): correctness + invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitmap import compress_rows, random_sparse
from repro.core.sidr import simulate


def _sim_case(seed, m, n, k, si, sw, reg_size=8):
    r = np.random.default_rng(seed)
    x = random_sparse((m, k), si, r)
    w = random_sparse((n, k), sw, r)
    bx, vx, nx = compress_rows(x)
    bw, vw, nw = compress_rows(w)
    st_ = simulate(bx, bw, vx, vw, nnz_i=nx, nnz_w=nw, reg_size=reg_size,
                   compute_values=True)
    return x, w, st_


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.0, 0.9), st.floats(0.0, 0.95))
def test_sidr_computes_exact_matmul(seed, si, sw):
    """The whole EIM+SIDR pipeline must produce X @ W^T exactly."""
    x, w, s = _sim_case(seed, 16, 16, 48, si, sw)
    np.testing.assert_allclose(s.outputs, x @ w.T, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(2, 16))
def test_sidr_any_reg_size(seed, reg):
    """Correct for any shared-register size (incl. degenerate reg=2)."""
    x, w, s = _sim_case(seed, 8, 8, 32, 0.4, 0.6, reg_size=reg)
    np.testing.assert_allclose(s.outputs, x @ w.T, atol=1e-4)
    assert s.deadlock_breaks == 0 or reg < 8  # 8-wide never deadlocks here


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_sram_reads_bounded_by_read_once(seed):
    """SIDR's headline property: every compressed SRAM word is read at most
    once per tile -> input/weight bytes <= total nnz (plus rare
    deadlock-break refetches)."""
    r = np.random.default_rng(seed)
    x = random_sparse((16, 64), 0.5, r)
    w = random_sparse((16, 64), 0.75, r)
    bx, vx, nx = compress_rows(x)
    bw, vw, nw = compress_rows(w)
    s = simulate(bx, bw, nnz_i=nx, nnz_w=nw)
    slack = 2 * s.deadlock_breaks
    assert s.input_bytes <= nx.sum() + slack
    assert s.weight_bytes <= nw.sum() + slack


def test_cycle_lower_bound_and_utilization():
    """Cycles >= max ops per PE; utilization = macs / (cycles * PEs)."""
    x, w, s = _sim_case(3, 16, 16, 128, 0.3, 0.75)
    per_pe = ((x != 0).astype(int) @ (w != 0).astype(int).T)
    assert s.max_cycles >= per_pe.max()
    assert s.macs == per_pe.sum()
    assert 0 < s.utilization <= 1.0


def test_dense_inputs_full_utilization():
    """Dense x dense = every PE fires every cycle (util 1.0, cycles = K)."""
    r = np.random.default_rng(0)
    x = r.standard_normal((16, 32)) + 10.0
    w = r.standard_normal((16, 32)) + 10.0
    bx, vx, nx = compress_rows(x)
    bw, vw, nw = compress_rows(w)
    s = simulate(bx, bw, vx, vw, nnz_i=nx, nnz_w=nw, compute_values=True)
    assert s.cycles == 32
    assert s.utilization == 1.0
    np.testing.assert_allclose(s.outputs, x @ w.T, rtol=1e-5)


def test_paper_fig5_two_pe_example():
    """Fig. 2/5 scenario: two PEs sharing one weight column window read the
    overlapping weights once."""
    # two input rows, one weight column, heavy overlap
    bmi = np.array([[1, 1, 0, 0, 1, 1, 1, 1],
                    [1, 0, 1, 1, 1, 0, 1, 1]], bool)
    bmw = np.array([[1, 0, 1, 1, 1, 1, 0, 1]], bool)
    s = simulate(bmi, bmw)
    # weights: nnz = 6, read-once => weight_bytes == 6
    assert s.weight_bytes == 6
    assert s.deadlock_breaks == 0


def test_batched_tiles_match_individual():
    r = np.random.default_rng(7)
    bmi = r.random((4, 8, 24)) < 0.5
    bmw = r.random((4, 8, 24)) < 0.5
    s_all = simulate(bmi, bmw)
    merged = None
    for t in range(4):
        s_t = simulate(bmi[t], bmw[t])
        merged = s_t if merged is None else merged.merge(s_t)
    assert s_all.macs == merged.macs
    assert s_all.cycles == merged.cycles
    assert s_all.input_bytes == merged.input_bytes
    assert s_all.weight_bytes == merged.weight_bytes
