"""Shared-prefix COW page reuse + recompute-on-preempt eviction.

Four pillars:

* refcounted-allocator properties under random share/fork/evict/retire
  interleavings (via the offline hypothesis shim): no double free, free
  xor referenced, conservation;
* equivalence — a shared-prefix hit run is token-identical to a cold run
  (and to the contiguous engine) across archs and sparsity, and a
  preempted-and-recomputed request's tokens are identical to an
  undisturbed run;
* the windowed-attention admission audit pin: ``possible``/``fits``
  both use the capped per-pool ``pages_for`` need, so a sliding-window
  request longer than its window is neither spuriously rejected nor
  over-committed;
* the bounded-history bugfix: engine memory and report cost stay
  O(history) while streaming aggregates keep report fields identical to
  the old full rescan on short traces.
"""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.serve import (PagedKVCache, RequestRejected, RollingStat,
                         ServeEngine)


def _run(cfg, trace, **kw):
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0, **kw)
    reqs = [eng.submit(**spec) for spec in trace]
    eng.run()
    return eng, [r.tokens for r in reqs]


def _shared_trace(n=4, plen=18, arrivals=40):
    """Same 18-token prompt (two full 8-token blocks + tail), staggered
    far enough apart that later requests admit after earlier ones
    retire — every request past the first can adopt resident pages."""
    prompt = list(range(1, plen + 1))
    return [{"prompt": prompt, "max_new_tokens": 5,
             "arrival": float(i * arrivals)} for i in range(n)]


# ------------------------------------------------ allocator properties ----


def _check_refcounts(kv):
    for b, pool in kv.pools.items():
        # table refs per page across slots + one per cache hold
        refs = {}
        for row in pool.table:
            for pg in row[row != 0].tolist():
                refs[pg] = refs.get(pg, 0) + 1
        for e in kv.prefix.values():
            pg = e.pages[b]
            refs[pg] = refs.get(pg, 0) + 1
        assert refs == pool.ref, f"{b}: refcounts drifted"
        # free xor referenced, conservation, no double free
        assert not set(refs) & set(pool.free), f"{b}: page free and live"
        assert len(set(pool.free)) == len(pool.free), f"{b}: double free"
        assert len(pool.free) + len(refs) == pool.pool_pages, \
            f"{b}: pages leaked"
        assert pool.in_use == len(refs)
        assert all(r >= 1 for r in refs.values())


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=4, max_size=40),
       st.integers(16, 64), st.sampled_from([4, 8]))
def test_refcount_invariants_under_random_interleavings(ops, pool_tokens,
                                                        page_len):
    """Random share/fork/evict/retire interleavings on a windowed+global
    arch: every transition preserves free-xor-referenced, exact
    refcounts, and page conservation."""
    cfg = get_smoke_config("gemma3-4b")
    kv = PagedKVCache(cfg, num_slots=3, max_len=32, page_len=page_len,
                      pool_tokens=pool_tokens, strict=False)
    tokens = list(range(100, 164))
    active = {}                              # slot -> next position
    rng = np.random.default_rng(pool_tokens * 101 + page_len)
    for op in ops:
        if op == 0 and len(active) < 3:      # admit (maybe with a hit)
            slot = next(s for s in range(3) if s not in active)
            need = int(rng.integers(4, 24))
            if not kv.reserve(need):
                continue
            _, blocks = kv.match_prefix(tokens[:need])
            kv.admit(slot, need, prefix=blocks)
            active[slot] = len(blocks) * page_len
        elif op == 1 and active:             # advance one slot (may fork)
            slot = int(rng.choice(list(active)))
            try:
                kv.ensure(slot, active[slot])
            except Exception:                # OutOfPages: drop the op
                continue
            active[slot] += 1
        elif op == 2 and active:             # register written blocks
            slot = int(rng.choice(list(active)))
            kv.register_prefix(slot, tokens, active[slot])
        elif op == 3:
            kv.evict_one()
        elif op == 4 and active:             # retire
            slot = int(rng.choice(list(active)))
            kv.retire(slot)
            del active[slot]
        elif op == 5:                        # retire-all then re-admit
            for slot in list(active):
                kv.retire(slot)
            active.clear()
        _check_refcounts(kv)
    for slot in list(active):
        kv.retire(slot)
        _check_refcounts(kv)
    while kv.evict_one():
        _check_refcounts(kv)
    for pool in kv.pools.values():           # everything drained
        assert pool.in_use == 0 and not pool.ref
        assert sorted(pool.free) == list(range(1, pool.pool_pages + 1))


# ------------------------------------------------------- equivalence -------


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-4b"])
@pytest.mark.parametrize("sparsity", [0.0, 0.75])
def test_prefix_hit_matches_cold_and_contiguous(arch, sparsity):
    """A shared-prefix hit run is token-identical to both the cold paged
    run and the contiguous engine — adopted pages reconstruct exactly
    the lines prefill would have written (gemma3's ring pools cap the
    shareable region at the window and COW-fork on wrap)."""
    cfg = get_smoke_config(arch)
    trace = _shared_trace()
    _, cont = _run(cfg, trace, sparsity=sparsity)
    _, cold = _run(cfg, trace, sparsity=sparsity, paged=True, page_len=8)
    eng, hot = _run(cfg, trace, sparsity=sparsity, paged=True, page_len=8,
                    prefix_reuse=True)
    assert hot == cold == cont
    pr = eng.report()["prefix_reuse"]
    assert pr["enabled"] and pr["hits"] >= 3 and pr["hit_tokens"] > 0
    reqs = list(eng.requests)
    assert reqs[0].prefix_hit_tokens == 0          # cold miss
    assert all(r.prefix_hit_tokens > 0 for r in reqs[1:])


def test_full_hit_skips_prefill_entirely():
    """With the whole prompt-minus-one resident, TTFT collapses to
    queue + first decode: the hit request spends zero steps ingesting
    (prompt positions are never teacher-forced or chunk-prefilled)."""
    cfg = get_smoke_config("olmo-1b")
    prompt = list(range(1, 18))               # 17 tokens: 2 blocks + last
    trace = [{"prompt": prompt, "max_new_tokens": 4, "arrival": 0.0},
             {"prompt": prompt, "max_new_tokens": 4, "arrival": 40.0}]
    eng, (t0, t1) = _run(cfg, trace, paged=True, page_len=8,
                         prefix_reuse=True)
    assert t0 == t1
    hit = list(eng.requests)[1]
    assert hit.prefix_hit_tokens == 16        # both full blocks adopted
    # admitted at pos 16: one decode step per generated token only
    assert hit.done_step - hit.admit_step + 1 == hit.max_new_tokens
    cold = list(eng.requests)[0]
    assert (cold.done_step - cold.admit_step + 1
            == len(prompt) - 1 + cold.max_new_tokens)


def test_prefix_hit_with_chunked_prefill_matches():
    cfg = get_smoke_config("olmo-1b")
    trace = _shared_trace()
    _, cold = _run(cfg, trace, paged=True, page_len=8)
    eng, hot = _run(cfg, trace, paged=True, page_len=8, prefill_chunk=4,
                    prefix_reuse=True)
    assert hot == cold
    assert eng.report()["prefix_reuse"]["hits"] >= 3


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-4b"])
def test_preempted_request_recomputes_identical_tokens(arch):
    """A pool too small for all four requests forces mid-flight
    preemption in relaxed-commitment mode; every request still emits
    exactly the tokens of the strict (undisturbed) run — preempted
    requests replay their own history, and position-folded sampling
    keys make the recompute deterministic."""
    cfg = get_smoke_config(arch)
    trace = [{"prompt": [i + 1, i + 2], "max_new_tokens": 12,
              "arrival": 0.0} for i in range(4)]

    def go(preempt):
        eng = ServeEngine(cfg, num_slots=4, max_len=32, seed=0,
                          paged=True, page_len=8, page_pool_tokens=48,
                          preempt=preempt)
        reqs = [eng.submit(**spec) for spec in trace]
        eng.run()
        return eng, [r.tokens for r in reqs]

    strict_eng, strict = go(False)
    relaxed_eng, relaxed = go(True)
    assert relaxed == strict
    pe = relaxed_eng.report()["prefix_reuse"]["preempt"]
    assert pe["enabled"] and pe["count"] >= 1
    assert pe["recomputed_tokens"] > 0
    assert any(r.t_preempt for r in relaxed_eng.requests)
    # relaxed commitment admits more concurrently at equal pool size
    assert (relaxed_eng.report()["slot_occupancy"]
            >= strict_eng.report()["slot_occupancy"])
    # drained clean: no page leaked through the preemption path
    assert relaxed_eng.report()["paging"]["pages_in_use"] == 0


def test_preempted_sampled_request_recomputes_identical_tokens():
    """Sampling keys fold the absolute position, so recompute determinism
    holds for sampled (not just greedy) requests."""
    cfg = get_smoke_config("olmo-1b")

    def go(preempt):
        eng = ServeEngine(cfg, num_slots=4, max_len=32, seed=0,
                          paged=True, page_len=8, page_pool_tokens=48,
                          preempt=preempt)
        reqs = [eng.submit([i + 1, i + 2], max_new_tokens=12,
                           temperature=1.0, seed=100 + i)
                for i in range(4)]
        eng.run()
        return eng, [r.tokens for r in reqs]

    _, strict = go(False)
    eng, relaxed = go(True)
    assert relaxed == strict
    assert eng.report()["prefix_reuse"]["preempt"]["count"] >= 1


def test_reuse_plus_preempt_token_identical_to_plain_paged():
    """The acceptance matrix: reuse+preempt on vs off, same tokens."""
    cfg = get_smoke_config("olmo-1b")
    trace = _shared_trace(n=5, arrivals=12)
    _, plain = _run(cfg, trace, paged=True, page_len=8)
    eng, both = _run(cfg, trace, paged=True, page_len=8,
                     page_pool_tokens=64, prefix_reuse=True, preempt=True)
    assert both == plain
    rep = eng.report()["prefix_reuse"]
    assert rep["enabled"] and rep["preempt"]["enabled"]


# --------------------------------------------------- fallback gating -------


def test_recurrent_arch_reuse_falls_back_with_reason():
    """Archs with recurrent mixer state can't skip ingestion (pages
    don't capture that state): prefix reuse records a fallback and the
    engine still serves correctly."""
    cfg = get_smoke_config("jamba-v0.1-52b")  # mamba + attn hybrid
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0,
                          paged=True, page_len=8, prefix_reuse=True)
    assert eng.prefix_reuse is False
    assert "recurrent" in eng.prefix_fallback
    assert any("prefix" in str(w.message) for w in caught)
    req = eng.submit([1, 2, 3], max_new_tokens=3)
    eng.run()
    assert len(req.tokens) == 3
    assert eng.report()["prefix_reuse"]["enabled"] is False


def test_unpaged_engine_gates_both_knobs():
    cfg = get_smoke_config("olmo-1b")
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0,
                          prefix_reuse=True, preempt=True)
    assert eng.prefix_reuse is False and eng.preempt is False
    assert "paged" in eng.prefix_fallback
    assert "paged" in eng.preempt_fallback


# ------------------------------------- windowed admission audit (pin) ------


def test_windowed_need_uses_capped_pages_on_both_sides():
    """Audit pin: a gemma3 request far longer than the sliding window
    must pass ``possible()`` with a pool sized for the *capped* page
    need (ring pools never touch more than their table width), and
    ``reserve``/``fits`` must commit the same capped number — the
    unwrapped token count appears on neither side."""
    cfg = get_smoke_config("gemma3-4b")       # window 8 locals + globals
    kv = PagedKVCache(cfg, num_slots=2, max_len=32, page_len=8)
    need = 31                                 # 4 unwrapped pages
    pf = kv.pages_for(need)
    for b, pool in kv.pools.items():
        if pool.ring:
            assert pool.page_slots == 1 and pf[b] == 1   # capped, not 4
        else:
            assert pf[b] == 4
    assert kv.possible(need)
    assert kv.reserve(need)
    for b, pool in kv.pools.items():
        assert pool.committed == pf[b]        # committed == capped need
    # a second worst-case request still fits: windowed pools are not
    # over-committed by the unwrapped length
    assert kv.fits(need)

    # end-to-end: window-exceeding requests serve (and aren't rejected)
    # through a pool sized only for the capped need
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0, paged=True,
                      page_len=8)
    req = eng.submit([1, 2, 3], max_new_tokens=28)    # need 30 >> window
    eng.run()
    assert len(req.tokens) == 28


# ------------------------------------------------- bounded history ---------


def test_request_history_is_bounded_with_exact_short_trace_stats():
    """The engine retains at most ``history`` retired requests and the
    scheduler at most ``history`` admission rids, while streaming
    aggregates keep short-trace report fields exact (count ≤ reservoir
    cap ⇒ identical to a full rescan)."""
    cfg = get_smoke_config("olmo-1b")
    eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0, history=3)
    reqs = [eng.submit([1 + i], max_new_tokens=2, arrival=float(2 * i))
            for i in range(8)]
    rep = eng.run()
    assert rep["requests"] == 8               # exact count, streamed
    assert rep["retained_requests"] == 3      # bounded retention
    assert len(eng.requests) == 3
    assert len(eng.scheduler.admitted_rids) == 3
    assert eng.scheduler.admitted_total == 8
    assert eng.scheduler.admitted_rids == [5, 6, 7]   # most recent, FIFO
    # streamed aggregates match a full rescan over all requests
    lats = sorted(r.latency_s for r in reqs)
    assert rep["generated_tokens"] == 16
    assert rep["latency_s"]["p50"] == pytest.approx(
        float(np.percentile(lats, 50)))
    assert rep["first_token_s"]["p50"] == pytest.approx(
        float(np.percentile([r.first_token_s for r in reqs], 50)))


def test_rolling_stat_exact_below_cap_and_bounded_above():
    rs = RollingStat(cap=8, seed=0)
    vals = [float(v) for v in range(1, 7)]
    for v in vals:
        rs.add(v)
    rs.add(None)                              # ignored, like the old scan
    assert rs.count == 6 and rs.mean == pytest.approx(3.5)
    assert rs.percentiles()["p50"] == pytest.approx(
        float(np.percentile(vals, 50)))
    for v in range(1000):
        rs.add(float(v))
    assert rs.count == 1006
    assert len(rs._sample) == 8               # reservoir stays bounded


def test_rejection_still_typed_with_reuse_enabled():
    cfg = get_smoke_config("olmo-1b")
    eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0, paged=True,
                      page_len=8, page_pool_tokens=8, prefix_reuse=True,
                      preempt=True)
    with pytest.raises(RequestRejected):
        eng.submit([1], max_new_tokens=16)    # exceeds the whole pool
    req = eng.submit([1], max_new_tokens=3)
    eng.run()
    assert len(req.tokens) == 3
