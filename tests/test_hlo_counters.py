"""HLO counter unit tests: fusion byte semantics, view-chain resolution,
trip-count extraction — the machinery the roofline numbers rest on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_counters import analyze, parse_computations


def _compiled(f, *avals):
    return jax.jit(f).lower(*avals).compile()


def test_matmul_flops_exact():
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((128, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 32), jnp.float32))
    assert analyze(c.as_text())["flops"] == pytest.approx(2 * 128 * 64 * 32)


def test_scan_trip_multiplier():
    def f(x, w):
        return jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=9)[0]
    c = _compiled(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                  jax.ShapeDtypeStruct((32, 32), jnp.float32))
    assert analyze(c.as_text())["flops"] == pytest.approx(2 * 32**3 * 9)


def test_scan_sliced_xs_not_charged_full():
    """Scan over stacked weights: each iteration must charge ~one slice of
    the stacked buffer, not the whole stack (the 20× inflation bug)."""
    P, D = 16, 64

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    c = _compiled(f, jax.ShapeDtypeStruct((D, D), jnp.float32),
                  jax.ShapeDtypeStruct((P, D, D), jnp.float32))
    r = analyze(c.as_text())
    stack_bytes = P * D * D * 4
    # total traffic should be O(P * slice) ~ a few x the stack, never
    # O(P * stack) = P x stack_bytes
    assert r["bytes"] < 8 * stack_bytes, r["bytes"] / stack_bytes


def test_dus_cache_update_charged_at_update_size():
    """Decode-style cache update in a scan: traffic ~ slice, not buffer."""
    P, C, D = 8, 256, 64

    def f(cache, xs):
        def body(carry, i):
            cache = carry
            upd = jnp.full((1, D), 1.0, jnp.float32)
            cache = jax.lax.dynamic_update_slice(cache, upd, (i, 0))
            return cache, None
        out, _ = jax.lax.scan(body, cache, xs)
        return out

    c = _compiled(f, jax.ShapeDtypeStruct((C, D), jnp.float32),
                  jax.ShapeDtypeStruct((P,), jnp.int32))
    r = analyze(c.as_text())
    buffer_bytes = C * D * 4
    assert r["bytes"] < 6 * buffer_bytes, r["bytes"] / buffer_bytes


@pytest.mark.parametrize("arch", ["olmo-1b", "granite-moe-3b-a800m"])
def test_real_jitted_serve_steps_analyzable(arch):
    """The analyzer must handle the *real* serving programs — jitted
    decode and prefill steps with their while-loops, DUS cache updates,
    and donated buffers — not just the synthetic shapes above.  The
    full {packed, dense} × {contig, paged} band matrix lives in
    test_traffic.py; this pins the analyzer side: real HLO parses, and
    the counted bytes sit at or above the dispatch's fetch floor."""
    from repro.configs import get_smoke_config
    from repro.serve import ServeEngine

    eng = ServeEngine(get_smoke_config(arch), seed=0, num_slots=2,
                      max_len=32, sparsity=0.5, paged=True, page_len=8,
                      prefill_chunk=8)
    for phase in ("decode", "prefill"):
        compiled = eng.traffic._lowered(phase).compile()
        r = analyze(compiled.as_text())
        assert r["flops"] > 0 and r["bytes"] > 0
        floor = eng.traffic.modeled_executed(phase)["total_bytes"]
        assert r["bytes"] >= floor, (phase, r["bytes"], floor)


def test_parse_handles_tuple_shapes_with_index_comments():
    """Shapes like (s32[], f32[8]{0}, /*index=5*/ f32[4]) must parse."""
    txt = """ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %t = (f32[8]{0}, s32[], /*index=2*/ f32[8]{0}) tuple(%a, %a, %a)
  ROOT %r = f32[8]{0} get-tuple-element(%t), index=0
}
"""
    comps = parse_computations(txt)
    assert "main" in comps
    ops = [i.op for i in comps["main"]]
    assert "tuple" in ops and "get-tuple-element" in ops
