"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the real single CPU device; only the dry-run (and the
subprocess-based SPMD tests) force 512/8 host devices."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
