"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the real single CPU device; only the dry-run (and the
subprocess-based SPMD tests) force 512/8 host devices.

This conftest also installs the offline property-testing shim: the
container has no ``hypothesis`` and cannot pip-install one, so when the
import fails we register ``tests/_hypothesis.py`` (a deterministic
``given``/``settings``/``strategies`` subset) under the same module names
before the property-test modules are collected.  With real hypothesis
installed the shim is never used.
"""
import pathlib
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401  (real library wins when present)
except ModuleNotFoundError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis
    _hypothesis.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
