"""Whole-stack bitmap weight streaming: packed-vs-dense equivalence,
manifest/fallback surfacing, traffic aggregation, sampling."""
import warnings

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.config import BlockCfg, ModelConfig
from repro.serve import ServeEngine, pack_model, poisson_trace


def _run_tokens(cfg, *, stream, sparsity=0.0, seed=0, n_requests=4,
                head_sparsity=None, **engine_kw):
    eng = ServeEngine(cfg, num_slots=2, max_len=32, sparsity=sparsity,
                      seed=seed, stream_weights=stream,
                      bitmap_head=stream, head_sparsity=head_sparsity,
                      **engine_kw)
    trace = poisson_trace(n_requests, rate=0.7, seed=3,
                          vocab_size=cfg.vocab_size, max_new=(4, 8))
    reqs = [eng.submit(**spec) for spec in trace]
    eng.run()
    return [r.tokens for r in reqs], eng


# ------------------------------------------------------- equivalence -------


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-4b",
                                  "granite-moe-3b-a800m"])
def test_packed_streaming_matches_dense_tokens(arch):
    """sparsity=0: the fully-packed engine reproduces the dense engine's
    tokens exactly, across attn/mlp, sliding-window and MoE archs —
    packing is lossless and the bitmap dispatch is numerically identical
    to dense ``@``."""
    cfg = get_smoke_config(arch)
    packed_toks, eng = _run_tokens(cfg, stream=True)
    dense_toks, _ = _run_tokens(cfg, stream=False)
    assert packed_toks == dense_toks
    assert all(toks for toks in packed_toks)
    assert eng.packed is not None and eng.packed.packed_entries


def test_packed_streaming_lossless_under_pruning():
    """At 75% sparsity the packed stream still equals dense dispatch of
    the *pruned* weights token-for-token (the budget keeps every
    surviving non-zero)."""
    cfg = get_smoke_config("olmo-1b")
    packed_toks, eng = _run_tokens(cfg, stream=True, sparsity=0.75)
    dense_toks, _ = _run_tokens(cfg, stream=False, sparsity=0.75)
    # dense engine serves a dense head; packed head is per-tensor pruned
    # to head_sparsity — neutralise by comparing hidden-stack effects
    # only via a 0-head-sparsity packed engine
    packed0, _ = _run_tokens(cfg, stream=True, sparsity=0.75,
                             head_sparsity=0.0)
    assert packed0 == dense_toks
    assert eng.report()["weight_stream"]["reduction"] > 2.0


# ------------------------------------------------- manifest / traffic ------


def test_pack_model_manifest_records_fallbacks():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    eng = ServeEngine(cfg, num_slots=2, max_len=16, sparsity=0.5, seed=0)
    pm = eng.packed
    packed_paths = {e.path for e in pm.packed_entries}
    assert any("attn/wq" in p for p in packed_paths)
    # MoE expert tensors are 3-D per period: recorded dense with a reason
    fb = {e.path: e.reason for e in pm.fallback_entries}
    assert any("moe" in p for p in fb)
    assert all(r for r in fb.values())
    ws = eng.report()["weight_stream"]
    assert ws["sparse_bytes_per_step"] < ws["dense_bytes_per_step"]
    assert ws["packed_tensors"] == len(pm.packed_entries)
    assert ws["fallbacks"] == {e.path: e.reason for e in pm.fallback_entries}


def test_dense_cache_not_counted_in_hbm_bytes():
    """The xla-oracle dense rendering must not change the modeled
    compressed-stream bytes."""
    from repro.sparse.format import pack_bitmap
    r = np.random.default_rng(0)
    w = r.standard_normal((64, 128)).astype(np.float32)
    w *= r.random((64, 128)) >= 0.75
    a = pack_bitmap(w, block=(64, 64))
    b = pack_bitmap(w, block=(64, 64), cache_dense=True)
    assert b.dense_cache is not None
    assert a.hbm_bytes == b.hbm_bytes
    np.testing.assert_array_equal(np.asarray(b.dense_cache), w)


def test_stacked_pack_roundtrip():
    """Period-stacked packing is lossless per period, shares one budget,
    and the stacked unpack oracle reproduces the input exactly."""
    from repro.sparse.format import (pack_bitmap_stacked,
                                     unpack_bitmap_stacked)
    r = np.random.default_rng(2)
    w = r.standard_normal((3, 64, 128)).astype(np.float32)
    w *= r.random((3, 64, 128)) >= 0.6
    bw = pack_bitmap_stacked(w, block=(64, 64))
    assert bw.packed_bits.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(unpack_bitmap_stacked(bw)), w)


def test_head_fallback_is_surfaced():
    """A head that no (BK, BN) tile divides must warn and report the
    fallback instead of silently claiming head_compression=1.0."""
    cfg = ModelConfig(name="oddvocab", d_model=32, num_layers=2,
                      num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=251,  # prime: no BN % 8 divisor
                      pattern=(BlockCfg(mixer="attn"),),
                      tie_embeddings=True, max_seq_len=32)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0,
                          stream_weights=False)
    assert eng.lm_weight is None
    assert eng.head_fallback and "vocab=251" in eng.head_fallback
    assert any("dense" in str(w.message) for w in caught)
    rep = eng.report()
    assert rep["head_fallback"] == eng.head_fallback
    assert rep["head_compression"] == 1.0


# ---------------------------------------------------------- sampling -------


def test_sampling_reproducible_and_greedy_unchanged():
    cfg = get_smoke_config("olmo-1b")

    def run(top_k):
        eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0, top_k=top_k)
        g = eng.submit([5], max_new_tokens=6)
        s = eng.submit([5], max_new_tokens=6, temperature=1.0, seed=11)
        eng.run()
        return g.tokens, s.tokens

    g1, s1 = run(top_k=8)
    g2, s2 = run(top_k=8)
    assert g1 == g2 and s1 == s2          # per-request seeds: deterministic
    assert s1 != g1                       # temperature actually samples
    # greedy requests are untouched by the sampling machinery
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0)
    g3 = eng.submit([5], max_new_tokens=6)
    eng.run()
    assert g3.tokens == g1

    _, s_notrunc = run(top_k=0)
    assert len(s_notrunc) == 6            # top_k=0 path also samples
