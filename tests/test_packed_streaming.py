"""Whole-stack bitmap weight streaming: packed-vs-dense equivalence,
manifest/fallback surfacing, traffic aggregation, sampling."""
import warnings

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.config import BlockCfg, ModelConfig
from repro.serve import ServeEngine, pack_model, poisson_trace


def _run_tokens(cfg, *, stream, sparsity=0.0, seed=0, n_requests=4,
                head_sparsity=None, **engine_kw):
    eng = ServeEngine(cfg, num_slots=2, max_len=32, sparsity=sparsity,
                      seed=seed, stream_weights=stream,
                      bitmap_head=stream, head_sparsity=head_sparsity,
                      **engine_kw)
    trace = poisson_trace(n_requests, rate=0.7, seed=3,
                          vocab_size=cfg.vocab_size, max_new=(4, 8))
    reqs = [eng.submit(**spec) for spec in trace]
    eng.run()
    return [r.tokens for r in reqs], eng


# ------------------------------------------------------- equivalence -------


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-4b",
                                  "granite-moe-3b-a800m"])
def test_packed_streaming_matches_dense_tokens(arch):
    """sparsity=0: the fully-packed engine reproduces the dense engine's
    tokens exactly, across attn/mlp, sliding-window and MoE archs —
    packing is lossless and the bitmap dispatch is numerically identical
    to dense ``@``."""
    cfg = get_smoke_config(arch)
    packed_toks, eng = _run_tokens(cfg, stream=True)
    dense_toks, _ = _run_tokens(cfg, stream=False)
    assert packed_toks == dense_toks
    assert all(toks for toks in packed_toks)
    assert eng.packed is not None and eng.packed.packed_entries


def test_packed_streaming_lossless_under_pruning():
    """At 75% sparsity the packed stream still equals dense dispatch of
    the *pruned* weights token-for-token (the budget keeps every
    surviving non-zero)."""
    cfg = get_smoke_config("olmo-1b")
    packed_toks, eng = _run_tokens(cfg, stream=True, sparsity=0.75)
    dense_toks, _ = _run_tokens(cfg, stream=False, sparsity=0.75)
    # dense engine serves a dense head; packed head is per-tensor pruned
    # to head_sparsity — neutralise by comparing hidden-stack effects
    # only via a 0-head-sparsity packed engine
    packed0, _ = _run_tokens(cfg, stream=True, sparsity=0.75,
                             head_sparsity=0.0)
    assert packed0 == dense_toks
    assert eng.report()["weight_stream"]["reduction"] > 2.0


# ------------------------------------------------- manifest / traffic ------


def test_pack_model_manifest_records_fallbacks():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    eng = ServeEngine(cfg, num_slots=2, max_len=16, sparsity=0.5, seed=0)
    pm = eng.packed
    packed_paths = {e.path for e in pm.packed_entries}
    assert any("attn/wq" in p for p in packed_paths)
    # MoE expert stacks ride the grouped bitmap layout since PR 5
    assert any("moe/w_gate" in p for p in packed_paths)
    fb = {e.path: e.reason for e in pm.fallback_entries}
    assert all(r for r in fb.values())
    ws = eng.report()["weight_stream"]
    assert ws["sparse_bytes_per_step"] < ws["dense_bytes_per_step"]
    assert ws["packed_tensors"] == len(pm.packed_entries)
    assert ws["fallbacks"] == {e.path: e.reason for e in pm.fallback_entries}


def test_dense_cache_not_counted_in_hbm_bytes():
    """The xla-oracle dense rendering must not change the modeled
    compressed-stream bytes."""
    from repro.sparse.format import pack_bitmap
    r = np.random.default_rng(0)
    w = r.standard_normal((64, 128)).astype(np.float32)
    w *= r.random((64, 128)) >= 0.75
    a = pack_bitmap(w, block=(64, 64))
    b = pack_bitmap(w, block=(64, 64), cache_dense=True)
    assert b.dense_cache is not None
    assert a.hbm_bytes == b.hbm_bytes
    np.testing.assert_array_equal(np.asarray(b.dense_cache), w)


def test_stacked_pack_roundtrip():
    """Period-stacked packing is lossless per period, shares one budget,
    and the stacked unpack oracle reproduces the input exactly."""
    from repro.sparse.format import (pack_bitmap_stacked,
                                     unpack_bitmap_stacked)
    r = np.random.default_rng(2)
    w = r.standard_normal((3, 64, 128)).astype(np.float32)
    w *= r.random((3, 64, 128)) >= 0.6
    bw = pack_bitmap_stacked(w, block=(64, 64))
    assert bw.packed_bits.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(unpack_bitmap_stacked(bw)), w)


def test_expert_pack_roundtrip_properties():
    """Property tests for the (P, E, K, N) expert layout: packing is
    lossless for any stack shape/sparsity, the value-slot budget is
    shared (= max tile non-zero count across the whole stack), and the
    grouped dispatch equals the dense per-expert einsum on both the xla
    ref and the interpreted Pallas kernel."""
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from repro.kernels import ops
    from repro.kernels.bitmap_spmm import group_slice
    from repro.sparse.format import (BitmapWeight, pack_bitmap_experts,
                                     unpack_bitmap_experts)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 2), st.integers(1, 4),
           st.sampled_from([16, 32, 64]), st.sampled_from([8, 16, 32]),
           st.floats(0.0, 0.95), st.integers(0, 2 ** 31 - 1))
    def check(p, e, k, n, sparsity, seed):
        r = np.random.default_rng(seed)
        w = r.standard_normal((p, e, k, n)).astype(np.float32)
        w *= r.random((p, e, k, n)) >= sparsity
        bw = pack_bitmap_experts(w, block=(k, n))
        assert bw.packed_bits.shape[:2] == (p, e)
        np.testing.assert_array_equal(np.asarray(unpack_bitmap_experts(bw)),
                                      w)
        tile_nnz = (w != 0).reshape(p * e, -1).sum(-1)
        assert bw.budget == max(1, int(tile_nnz.max()))
        # grouped dispatch == per-expert dense matmul (one period slice)
        per = BitmapWeight(packed_bits=bw.packed_bits[0],
                           values=bw.values[0], row_start=bw.row_start[0],
                           shape=bw.shape, block=bw.block)
        x = r.standard_normal((e, 3, k)).astype(np.float32)
        want = np.einsum("gmk,gkn->gmn", x, w[0])
        got = ops.bitmap_spmm_grouped(x, per, impl="xla")
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=2e-5)
        assert np.asarray(group_slice(per, e - 1).values).shape == \
            bw.values.shape[2:]

    check()

    # the interpreted Pallas kernel agrees on one representative stack
    r = np.random.default_rng(7)
    w = r.standard_normal((3, 64, 32)).astype(np.float32)
    w *= r.random((3, 64, 32)) >= 0.7
    bw = pack_bitmap_experts(w[None], block=(64, 32))
    per = BitmapWeight(packed_bits=bw.packed_bits[0], values=bw.values[0],
                       row_start=bw.row_start[0], shape=bw.shape,
                       block=bw.block)
    x = r.standard_normal((3, 4, 64)).astype(np.float32)
    got = ops.bitmap_spmm_grouped(x, per, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.einsum("gmk,gkn->gmn", x, w),
                               rtol=1e-4, atol=1e-4)


def test_head_fallback_is_surfaced():
    """A head that no (BK, BN) tile divides must warn and report the
    fallback instead of silently claiming head_compression=1.0."""
    cfg = ModelConfig(name="oddvocab", d_model=32, num_layers=2,
                      num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=251,  # prime: no BN % 8 divisor
                      pattern=(BlockCfg(mixer="attn"),),
                      tie_embeddings=True, max_seq_len=32)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0,
                          stream_weights=False)
    assert eng.lm_weight is None
    assert eng.head_fallback and "vocab=251" in eng.head_fallback
    assert any("dense" in str(w.message) for w in caught)
    rep = eng.report()
    assert rep["head_fallback"] == eng.head_fallback
    assert rep["head_compression"] == 1.0


# -------------------------------------------- full-stack coverage (PR 5) ---
#
# MoE expert stacks and SSM mixer projections ride the compressed bitmap
# path: token equivalence across the 5-arch × 2-sparsity × {decode,
# chunked prefill} × {contiguous, paged} matrix, a manifest snapshot
# locking per-arch fallbacks, expert-layout roundtrip properties, and
# the per-activated-expert traffic accounting rule.


def _mamba_smoke_cfg():
    """Pure-mamba decode cell (no registry arch is mamba-only; jamba
    interleaves).  d_state=6 makes x_proj's column count (dtr + 2N = 16)
    tileable, so all four mamba GEMMs pack."""
    return ModelConfig(
        name="mamba-smoke", d_model=64, num_layers=2, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256,
        pattern=(BlockCfg(mixer="mamba", ffn="mlp"),),
        mamba_d_state=6, mamba_expand=2, mamba_conv=4,
        norm="rmsnorm", act="silu", tie_embeddings=False, max_seq_len=64)


MATRIX_ARCHS = ["granite-moe-3b-a800m", "moonshot-v1-16b-a3b",
                "jamba-v0.1-52b", "mamba", "rwkv6-3b"]


def _matrix_cfg(arch):
    return _mamba_smoke_cfg() if arch == "mamba" else get_smoke_config(arch)


_ORACLE_TOKENS = {}     # (cfg.name, sparsity) -> dense decode tokens


def _oracle_tokens(cfg, sparsity):
    key = (cfg.name, sparsity)
    if key not in _ORACLE_TOKENS:
        _ORACLE_TOKENS[key] = _run_tokens(cfg, stream=False,
                                          sparsity=sparsity,
                                          n_requests=3)[0]
    return _ORACLE_TOKENS[key]


@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
@pytest.mark.parametrize("mode", ["decode", "prefill"])
@pytest.mark.parametrize("arch", MATRIX_ARCHS)
def test_full_stack_packed_matrix(arch, mode, paged):
    """The fully-packed engine — MoE expert stacks, SSM mixers, router
    and channel-mix included — reproduces the dense contiguous decode
    oracle token-for-token at sparsity 0 and 0.75, under chunked prefill
    and paging.  Archs whose engine records a fallback for a mode
    (recurrent mixers under prefill, attention-free archs under paging)
    still serve token-identically through the fallback."""
    cfg = _matrix_cfg(arch)
    kw = {}
    if mode == "prefill":
        kw["prefill_chunk"] = 2
    if paged:
        kw.update(paged=True, page_len=8)
    for sparsity in (0.0, 0.75):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            toks, eng = _run_tokens(cfg, stream=True, sparsity=sparsity,
                                    head_sparsity=0.0, n_requests=3, **kw)
        assert toks == _oracle_tokens(cfg, sparsity), (arch, mode, paged,
                                                       sparsity)
        assert all(t for t in toks)
        # the retired blanket reason must never reappear
        assert not any(e.reason == "no compressed dispatch path"
                       for e in eng.packed.manifest)


# Per-arch fallback snapshot: the exact (component, tensor) classes that
# may serve dense.  Everything is either a non-GEMM tensor (norms, conv,
# elementwise SSM state maps) or a smoke shape no (BK, BN) tile divides
# (granite's 5-way router, jamba's 16-wide x_proj columns... which is
# 12 here).  A regression that silently drops a GEMM class to dense —
# or grows a new fallback — fails this snapshot.
EXPECTED_FALLBACKS = {
    "granite-moe-3b-a800m": {("attn", "norm"), ("moe", "norm"),
                             ("moe", "router")},         # router (64, 5)
    "moonshot-v1-16b-a3b": {("attn", "norm"), ("moe", "norm")},
    "jamba-v0.1-52b": {("attn", "norm"), ("mlp", "norm"), ("moe", "norm"),
                       ("moe", "router"),                # router (64, 4)
                       ("mamba", "norm"), ("mamba", "conv_w"),
                       ("mamba", "conv_b"), ("mamba", "dt_bias"),
                       ("mamba", "A_log"), ("mamba", "D"),
                       ("mamba", "x_proj")},             # x_proj (128, 12)
    "mamba": {("mlp", "norm"), ("mamba", "norm"), ("mamba", "conv_w"),
              ("mamba", "conv_b"), ("mamba", "dt_bias"),
              ("mamba", "A_log"), ("mamba", "D")},
    "rwkv6-3b": {("rwkv", "norm"), ("rwkv", "mix_mu"), ("rwkv", "w0"),
                 ("rwkv", "u"), ("rwkv", "gn_scale"),
                 ("rwkv_cm", "norm"), ("rwkv_cm", "cm_mu")},
}


@pytest.mark.parametrize("arch", MATRIX_ARCHS)
def test_manifest_fallback_snapshot(arch):
    """Zero unexpected fallbacks per arch family, and every fallback is
    a non-GEMM tensor or an un-tileable smoke shape — never a GEMM class
    missing its dispatch path."""
    import jax
    from repro.models.model import init_params
    cfg = _matrix_cfg(arch)
    pm = pack_model(init_params(jax.random.PRNGKey(0), cfg))
    got = {tuple(e.path.split("/")[-2:]) for e in pm.fallback_entries}
    assert got == EXPECTED_FALLBACKS[arch], (arch, got)
    for e in pm.fallback_entries:
        assert ("not a GEMM operand" in e.reason
                or "no (BK, BN) tile" in e.reason), (e.path, e.reason)
    # expert stacks carry the grouped layout and their stored count;
    # rwkv's always-active mix_B is grouped but not router-gated
    for e in pm.packed_entries:
        comp, name = e.path.split("/")[-2:]
        if comp == "moe" and name in ("w_gate", "w_up", "w_down"):
            assert e.layout == "grouped" and e.experts == cfg.num_experts
        elif (comp, name) == ("rwkv", "mix_B"):
            assert e.layout == "grouped" and e.experts == 0
        else:
            assert e.layout == "stacked" and e.experts == 0


def test_expert_stream_accounting():
    """report()["weight_stream"] counts expert tensors once per
    *activated* expert per step (min(E, num_slots·top_k)), not once per
    stored expert — and matches a by-hand aggregation of the manifest."""
    cfg = get_smoke_config("granite-moe-3b-a800m")   # E=5, moe top_k=2
    eng = ServeEngine(cfg, num_slots=2, max_len=16, sparsity=0.5, seed=0,
                      head_sparsity=0.0)
    ws = eng.report()["weight_stream"]
    activated = min(cfg.num_experts, 2 * cfg.top_k)  # 4 of 5 experts
    assert ws["activated_experts"] == 2 * cfg.top_k

    def scaled(e, attr):
        b = getattr(e, attr)
        if e.experts:
            b = int(round(b * min(e.experts, ws["activated_experts"])
                          / e.experts))
        return b

    # granite's smoke vocab (255, deliberately non-divisible) makes the
    # head fall back to dense, so both sides carry the dense head term
    head_dense = cfg.d_model * cfg.vocab_size * 4
    head = (eng.lm_weight.hbm_bytes if eng.lm_weight is not None
            else head_dense)
    want_sparse = head + sum(scaled(e, "sparse_bytes")
                             for e in eng.packed.manifest)
    want_dense = head_dense + sum(scaled(e, "dense_bytes")
                                  for e in eng.packed.manifest)
    assert ws["sparse_bytes_per_step"] == want_sparse
    assert ws["dense_bytes_per_step"] == want_dense
    # the activation scaling actually bites: stored-stack totals are
    # strictly larger than the per-step activated accounting
    stored = head + sum(e.sparse_bytes for e in eng.packed.manifest)
    assert want_sparse < stored
    assert activated == 4


# ---------------------------------------------------------- sampling -------


def test_sampling_reproducible_and_greedy_unchanged():
    cfg = get_smoke_config("olmo-1b")

    def run(top_k):
        eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0, top_k=top_k)
        g = eng.submit([5], max_new_tokens=6)
        s = eng.submit([5], max_new_tokens=6, temperature=1.0, seed=11)
        eng.run()
        return g.tokens, s.tokens

    g1, s1 = run(top_k=8)
    g2, s2 = run(top_k=8)
    assert g1 == g2 and s1 == s2          # per-request seeds: deterministic
    assert s1 != g1                       # temperature actually samples
    # greedy requests are untouched by the sampling machinery
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0)
    g3 = eng.submit([5], max_new_tokens=6)
    eng.run()
    assert g3.tokens == g1

    _, s_notrunc = run(top_k=0)
    assert len(s_notrunc) == 6            # top_k=0 path also samples
