"""EIM (paper §II-C): equivalence of the three formulations + invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.eim import (EimStreams, eim_reference, eim_streams,
                            eim_two_step)

bitmap_st = st.lists(st.booleans(), min_size=1, max_size=64)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.05, 0.95), st.floats(0.05, 0.95))
def test_reference_equals_two_step(seed, si, sw):
    r = np.random.default_rng(seed)
    bmi = r.random(48) < si
    bmw = r.random(48) < sw
    a_i, a_w = eim_reference(bmi, bmw)
    b_i, b_w = eim_two_step(bmi, bmw)
    np.testing.assert_array_equal(a_i, b_i)
    np.testing.assert_array_equal(a_w, b_w)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_streams_match_reference(seed):
    r = np.random.default_rng(seed)
    m, n, k = 4, 5, 32
    bmi = r.random((m, k)) < 0.5
    bmw = r.random((n, k)) < 0.4
    s = eim_streams(bmi, bmw)
    for i in range(m):
        for j in range(n):
            ri, rw = eim_reference(bmi[i], bmw[j])
            L = s.length[i, j]
            assert L == len(ri)
            np.testing.assert_array_equal(s.eff_i[i, j, :L], ri)
            np.testing.assert_array_equal(s.eff_w[i, j, :L], rw)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_effective_index_invariants(seed):
    """EffI/EffW are strictly increasing and bounded by the nnz counts —
    the property that makes the SIDR shared window slide monotonically."""
    r = np.random.default_rng(seed)
    bmi = r.random(64) < r.uniform(0.1, 0.9)
    bmw = r.random(64) < r.uniform(0.1, 0.9)
    ei, ew = eim_reference(bmi, bmw)
    assert len(ei) == int((bmi & bmw).sum())
    if len(ei):
        assert (np.diff(ei) > 0).all() and (np.diff(ew) > 0).all()
        assert ei.max() < bmi.sum() and ew.max() < bmw.sum()


def test_paper_fig1_example():
    """The worked bitmaps of Fig. 1: I0 = 10101111, W0 = 01101110."""
    bmi0 = np.array([1, 0, 1, 0, 1, 1, 1, 1], bool)   # compressed size 6
    bmw0 = np.array([0, 1, 1, 0, 1, 1, 1, 0], bool)   # compressed size 5
    ei, ew = eim_reference(bmi0, bmw0)
    # BMNZ = 00101110: non-zero ops at original indexes 2, 4, 5, 6;
    # their ranks inside the compressed buffers:
    np.testing.assert_array_equal(ei, [1, 2, 3, 4])
    np.testing.assert_array_equal(ew, [1, 2, 3, 4])


def test_padding_is_invalid_marker():
    s = eim_streams(np.ones((1, 8), bool), np.zeros((1, 8), bool))
    assert s.length[0, 0] == 0
    assert (s.eff_i == EimStreams.INVALID).all()
