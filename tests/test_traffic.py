"""Memory-traffic observatory tests (serve/traffic.py).

The load-bearing guarantees:

* **ledger == weight_stream, to the byte** — the per-role attribution
  reuses the manifest's exact per-entry accounting, so its sums equal
  the ``weight_stream`` aggregates exactly (packed and dense-baseline,
  MoE activated-expert scaling included), and stay equal after a
  quarantine flips entries to dense;
* **modeled-vs-compiled** — the cross-check lowers the engine's real
  jitted decode/prefill steps, counts bytes with the while-aware HLO
  analyzer, and the ratio against the modeled fetch floor sits inside
  the per-phase tolerance band across {packed, dense} × {contig,
  paged} on two archs (this is also the hlo_counters real-step
  coverage the synthetic GEMM/scan tests don't give);
* **off == on** — a ``traffic_out``-less engine serves bit-identical
  tokens and holds no artifact state; the ledger's counters live in
  the always-on registry like every other subsystem's;
* the trace gains ``hbm.*`` counter tracks that reconcile with the
  registry totals, and the artifact round-trips through
  ``scripts/traffic_report.py``'s budget gate.
"""
import json
import pathlib
import subprocess
import sys

import pytest

from repro.configs import get_smoke_config
from repro.serve import ServeEngine, load_trace, poisson_trace
from repro.serve.traffic import (CROSSCHECK_BANDS, TRAFFIC_KINDS,
                                 TRAFFIC_PHASES, role_of)

ARCHS = ["olmo-1b", "granite-moe-3b-a800m"]

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _engine(arch="olmo-1b", **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("sparsity", 0.5)
    return ServeEngine(get_smoke_config(arch), seed=0, **kw)


def _run(eng, requests=3, seed=0):
    trace = poisson_trace(requests, rate=0.5, seed=seed,
                          vocab_size=eng.cfg.vocab_size,
                          prompt_len=(1, 4), max_new=(2, 5))
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)
        rep = eng.run()
    return rep, [(r.rid, r.state.name, list(r.tokens))
                 for r in eng.requests]


# ------------------------------------------------------------ role map ----

def test_role_of():
    assert role_of("blocks/b0/attn/wq") == "attn.wq"
    assert role_of("blocks/b0/attn/wo") == "attn.wo"
    assert role_of("blocks/b0/attn/norm") == "norm"
    assert role_of("blocks/b0/mlp/w_up") == "mlp"
    assert role_of("blocks/b0/moe/router") == "moe.router"
    assert role_of("blocks/b0/moe/w_gate") == "moe.experts"
    assert role_of("blocks/b0/mamba/in_proj") == "ssm"
    assert role_of("blocks/b0/rwkv/wk") == "ssm"


# --------------------------------------------- ledger == weight_stream ----

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("stream", [True, False],
                         ids=["packed", "dense"])
def test_ledger_sums_exactly_to_weight_stream(arch, stream):
    eng = _engine(arch, stream_weights=stream, bitmap_head=stream)
    rep, _ = _run(eng)
    ws, roles = rep["weight_stream"], rep["traffic"]["per_role"]
    assert sum(r["sparse_bytes"] for r in roles.values()) \
        == ws["sparse_bytes_per_step"]
    assert sum(r["dense_bytes"] for r in roles.values()) \
        == ws["dense_bytes_per_step"]
    w = rep["traffic"]["weight"]
    assert w["sparse_bytes_per_step"] == ws["sparse_bytes_per_step"]
    assert w["dense_bytes_per_step"] == ws["dense_bytes_per_step"]
    assert w["reduction"] == pytest.approx(ws["reduction"])
    # roles carry the arch's expected structure
    if eng.cfg.num_experts:
        assert "moe.experts" in roles and "moe.router" in roles
    assert "head" in roles


def test_ledger_tracks_quarantine():
    eng = _engine()
    before = eng.traffic.per_role()
    path = next(e for e in eng.packed.manifest if e.packed).path
    eng.packed.quarantine(path, "test")
    eng.traffic.invalidate()
    after = eng.traffic.per_role()
    role = role_of(path)
    assert after[role]["sparse_bytes"] > before[role]["sparse_bytes"]
    # the exactness pin must survive the quarantine
    ws = eng.weight_stream_report()
    assert sum(r["sparse_bytes"] for r in after.values()) \
        == ws["sparse_bytes_per_step"]


# ----------------------------------- modeled vs compiled (hlo_counters) ----

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("knobs", [
    {"stream_weights": True, "bitmap_head": True},
    {"stream_weights": False, "bitmap_head": False},
    {"stream_weights": True, "bitmap_head": True,
     "paged": True, "page_len": 8},
    {"stream_weights": True, "bitmap_head": True,
     "paged": True, "page_len": 8, "prefill_chunk": 8},
], ids=["packed-contig", "dense-contig", "packed-paged",
        "packed-paged-prefill"])
def test_crosscheck_within_band(arch, knobs):
    eng = _engine(arch, **knobs)
    cc = eng.traffic.crosscheck()
    assert cc["dispatch"] in ("xla-oracle", "pallas", "dense")
    assert "decode" in cc
    if knobs.get("prefill_chunk"):
        assert "prefill" in cc
    for phase in ("decode", "prefill"):
        if phase not in cc:
            continue
        e = cc[phase]
        lo, hi = CROSSCHECK_BANDS[phase]
        assert e["compiled_bytes"] > 0
        assert e["compiled_flops"] > 0
        # the modeled side is a fetch floor: compiled can only exceed it
        assert e["ratio"] >= lo, (phase, e)
        assert e["ratio"] <= hi, (phase, e)
        assert e["within_band"]
        assert e["modeled"]["total_bytes"] \
            == (e["modeled"]["weight_bytes"] + e["modeled"]["head_bytes"]
                + e["modeled"]["kv_bytes"])
    # the cached verdict surfaces in report()
    assert eng.report()["traffic"]["crosscheck"] is cc


def test_crosscheck_floor_scales_with_dispatch():
    """The xla-oracle dispatch fetches dense renderings, so its floor
    must sit above the (hypothetical) pallas floor of the same pack."""
    eng = _engine()
    oracle = eng.traffic.modeled_executed("decode")
    sparse_stream = eng.weight_stream_report()["sparse_bytes_per_step"]
    assert oracle["weight_bytes"] + oracle["head_bytes"] > sparse_stream


# -------------------------------------------------------- phase hooks ----

def test_phase_counters_accumulate_and_match_trace(tmp_path):
    trace_path = tmp_path / "t.json"
    eng = _engine(paged=True, page_len=8, prefill_chunk=8,
                  trace_out=str(trace_path))
    rep, _ = _run(eng, requests=4)
    ph = rep["traffic"]["phases"]
    assert ph["decode"]["steps"] > 0
    assert ph["decode"]["weight_bytes"] \
        == ph["decode"]["steps"] * rep["traffic"]["weight"][
            "sparse_bytes_per_step"]
    assert ph["prefill"]["calls"] > 0
    assert ph["prefill"]["kv_write_bytes"] > 0
    # prefill streams the stack only (no LM head application)
    stack = (rep["traffic"]["weight"]["sparse_bytes_per_step"]
             - rep["traffic"]["per_role"]["head"]["sparse_bytes"])
    assert ph["prefill"]["weight_bytes"] == ph["prefill"]["calls"] * stack
    eng.close()
    events = load_trace(str(trace_path))
    by_track = {}
    for e in events:
        if e.get("ph") == "C" and e.get("cat") == "traffic":
            for k, v in e["args"].items():
                by_track.setdefault((e["name"], k), 0)
                by_track[(e["name"], k)] += v
    for phase, track in (("decode", "hbm.decode"),
                         ("prefill", "hbm.prefill")):
        for kind in TRAFFIC_KINDS:
            assert by_track[(track, f"{kind}_bytes")] \
                == ph[phase][f"{kind}_bytes"], (phase, kind)


def test_registry_counters_registered():
    eng = _engine()
    for phase in TRAFFIC_PHASES:
        for kind in TRAFFIC_KINDS:
            assert f"traffic.{phase}.{kind}_bytes" in eng.metrics.names


# ------------------------------------------------------------ off == on ----

def test_traffic_off_is_identical_and_stateless(tmp_path):
    eng_off = _engine(paged=True, page_len=8, prefill_chunk=8)
    assert eng_off.traffic_out is None
    _, served_off = _run(eng_off, requests=4)
    assert eng_off.close() == []
    assert eng_off.traffic._crosscheck is None   # nothing compiled

    out = tmp_path / "traffic.json"
    eng_on = _engine(paged=True, page_len=8, prefill_chunk=8,
                     traffic_out=str(out))
    _, served_on = _run(eng_on, requests=4)
    assert served_on == served_off
    assert eng_on.close() == [str(out)]
    assert eng_on.close() == []                  # idempotent
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro.serve.traffic/v1"
    assert doc["traffic"]["crosscheck"]["decode"]["within_band"]


# ------------------------------------------------- energy + roofline ----

def test_energy_and_roofline_projection():
    eng = _engine()
    rep, _ = _run(eng)
    en = rep["traffic"]["energy"]
    assert en["macs_per_token"] > 0
    assert 0 < en["pj_per_token"] < en["pj_per_token_dense"]
    assert en["tops_per_watt"] > en["tops_per_watt_dense"] > 0
    rl = rep["traffic"]["roofline"]
    assert "decode" in rl
    assert rl["decode"]["bottleneck"] in ("compute", "memory",
                                          "collective")
    assert rl["decode"]["memory_s"] > 0


# --------------------------------------------------- tooling round-trip ----

def test_traffic_report_budget_gate(tmp_path):
    out = tmp_path / "traffic.json"
    eng = _engine(traffic_out=str(out))
    _run(eng)
    eng.close()
    budget = tmp_path / "budget.json"
    script = str(_ROOT / "scripts" / "traffic_report.py")
    env_path = str(_ROOT / "src")
    seed = subprocess.run(
        [sys.executable, script, str(out), "--budget", str(budget),
         "--update-budget"],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert seed.returncode == 0, seed.stdout + seed.stderr
    gate = subprocess.run(
        [sys.executable, script, str(out), "--budget", str(budget)],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert gate.returncode == 0, gate.stdout + gate.stderr
    assert "ok" in gate.stdout
    # shrink the budget far below the measured bytes: the gate must fail
    b = json.loads(budget.read_text())
    for entry in b.values():
        for k, v in list(entry.items()):
            if k.endswith("bytes_per_step") or k.endswith("_bytes"):
                entry[k] = int(v * 0.5)
    budget.write_text(json.dumps(b))
    fail = subprocess.run(
        [sys.executable, script, str(out), "--budget", str(budget)],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert fail.returncode == 1
    assert "REGRESSED" in fail.stdout
