"""The §Perf optimizations must be *numerically* equivalent to baseline
mode — sharding/layout changes are allowed to change traffic, never math."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import forward, init_params, loss_fn
from repro.models.layers import moe_ffn
from repro.models.config import BlockCfg, ModelConfig


def _with_mode(mode, fn):
    old = os.environ.get("REPRO_PERF_MODE")
    try:
        if mode:
            os.environ["REPRO_PERF_MODE"] = mode
        else:
            os.environ.pop("REPRO_PERF_MODE", None)
        return fn()
    finally:
        if old is None:
            os.environ.pop("REPRO_PERF_MODE", None)
        else:
            os.environ["REPRO_PERF_MODE"] = old


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-12b",
                                  "granite-moe-3b-a800m"])
def test_loss_parity_baseline_vs_optimized(arch, rng):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                               jnp.int32),
    }
    base = _with_mode("baseline",
                      lambda: float(loss_fn(params, batch, cfg)[0]))
    opt = _with_mode(None, lambda: float(loss_fn(params, batch, cfg)[0]))
    # For MoE archs the per-row and global dispatch variants can drop
    # *different* overflow tokens at the capacity boundary, so parity is
    # approximate: measured delta for granite on this batch is 3.46e-4
    # (dense archs are bit-identical).
    assert base == pytest.approx(opt, abs=5e-4)


def test_moe_parity_per_row_vs_global_dispatch(rng):
    """With capacity high enough that neither variant drops tokens, the
    per-row and global dispatch must agree exactly."""
    cfg = ModelConfig(name="t", d_model=32, num_layers=1, num_heads=4,
                      num_kv_heads=4, d_ff=64, vocab_size=64,
                      pattern=(BlockCfg(ffn="moe"),), num_experts=4,
                      top_k=2, capacity_factor=8.0)
    params = {
        "router": jnp.asarray(rng.standard_normal((32, 4)), jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((4, 32, 64)) * 0.1,
                              jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((4, 32, 64)) * 0.1,
                            jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((4, 64, 32)) * 0.1,
                              jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((3, 8, 32)), jnp.float32)
    a = _with_mode("baseline", lambda: np.asarray(moe_ffn(params, x, cfg)))
    b = _with_mode(None, lambda: np.asarray(moe_ffn(params, x, cfg)))
    np.testing.assert_allclose(a, b, atol=1e-5)
