"""Continuous-batching engine: scheduler invariants + decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.steps import build_serve_step
from repro.models.model import init_cache
from repro.serve import (Request, RequestState, ServeEngine, SlotScheduler,
                         poisson_trace)


def _mk_requests(specs):
    return [Request(rid=i, prompt=[1], max_new_tokens=4, arrival=a)
            for i, a in enumerate(specs)]


# ---------------------------------------------------------- scheduler ------


def test_scheduler_fifo_and_slot_reuse():
    s = SlotScheduler(2)
    reqs = _mk_requests([0.0, 0.0, 1.0, 5.0])
    for r in reqs:
        s.submit(r)
    admitted = s.admit(0.0)
    assert [r.rid for _, r in admitted] == [0, 1]
    assert not s.free
    # nothing free: arrival-due request 2 must wait
    assert s.admit(2.0) == []
    s.release(0)
    # freed slot 0 is reused by the earliest waiting request
    (slot, r2), = s.admit(2.0)
    assert slot == 0 and r2.rid == 2
    # request 3 not due yet
    assert s.admit(2.0) == []
    assert s.has_work


def test_scheduler_no_starvation_under_trace():
    """FIFO by (arrival, rid): admission order equals arrival order even
    when the queue backs up far beyond the slot count."""
    s = SlotScheduler(2)
    r = np.random.default_rng(3)
    arrivals = np.cumsum(r.exponential(0.5, 20))
    reqs = _mk_requests(list(arrivals))
    for req in reqs:
        s.submit(req)
    step = 0
    ttl = {}  # slot -> remaining steps
    while s.has_work and step < 1000:
        for slot, req in s.admit(float(step)):
            ttl[slot] = 3
        for slot in [sl for sl in list(ttl) if sl in s.active]:
            ttl[slot] -= 1
            if ttl[slot] <= 0:
                s.release(slot)
                del ttl[slot]
        step += 1
    assert not s.has_work, "requests starved"
    assert s.admitted_rids == sorted(s.admitted_rids)
    assert len(s.free) == s.num_slots
    assert all(r.state == RequestState.DONE for r in reqs)


# ------------------------------------------------------------- engine ------


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("olmo-1b")
    return ServeEngine(cfg, num_slots=2, max_len=32, sparsity=0.5, seed=0)


def test_lone_request_matches_straight_line_serve(engine):
    """Engine tokens for a single request must equal the old straight-line
    serve() loop (lock-step batch decode, scalar positions) token for
    token — continuous batching changes scheduling, never the math."""
    steps = 10
    req = engine.submit([7], max_new_tokens=steps)
    engine.run()
    assert len(req.tokens) == steps

    cfg = engine.cfg
    step = jax.jit(build_serve_step(cfg), donate_argnums=(1,))
    cache = init_cache(cfg, 1, 32)
    tok = jnp.asarray([[7]], jnp.int32)
    ref = []
    for pos in range(steps):
        nxt, _, cache = step(engine.params, cache, tok, jnp.int32(pos),
                             lm_weight=engine.lm_weight)
        tok = nxt[:, None]
        ref.append(int(nxt[0]))
    assert req.tokens == ref


def test_continuous_batching_drains_and_reuses_slots():
    """More requests than slots under staggered arrivals: every request
    completes its budget, freed slots are recycled mid-flight, and the
    engine drains cleanly.  Fresh engine: arrivals must land relative to
    step 0 for the stagger to be real."""
    engine = ServeEngine(get_smoke_config("olmo-1b"), num_slots=2,
                         max_len=32, sparsity=0.5, seed=0)
    trace = poisson_trace(6, rate=0.7, seed=2,
                          vocab_size=engine.cfg.vocab_size, max_new=(4, 8))
    reqs = [engine.submit(**spec) for spec in trace]
    engine.run()

    assert all(r.state == RequestState.DONE for r in reqs)
    assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
    # 6 requests over 2 slots: at least one slot served multiple requests
    slots = [r.slot for r in reqs]
    assert max(slots.count(s) for s in set(slots)) >= 2
    # mid-flight admission: some admission happened after another request
    # finished but while a third was still decoding (no drain barrier)
    admits = sorted(r.admit_step for r in reqs)
    dones = sorted(r.done_step for r in reqs)
    assert admits[-1] > dones[0]
    # drained: all slots free, queue empty
    assert not engine.scheduler.has_work
    assert len(engine.scheduler.free) == engine.num_slots
    # FIFO admission order
    rids = engine.scheduler.admitted_rids
    assert rids == sorted(rids)


def test_multi_token_prompt_teacher_forcing(engine):
    """Prompt tokens are consumed before generation starts; the generated
    count still honours max_new_tokens exactly."""
    req = engine.submit([3, 5, 7], max_new_tokens=5)
    engine.run()
    assert len(req.tokens) == 5
    assert req.done_step - req.admit_step + 1 == len(req.prompt) - 1 + 5


def test_bitmap_head_is_packed_and_engaged(engine):
    """The LM head is packed once into BitmapWeight and compresses at the
    engine's pruning level (the kernels/ops path runs every step)."""
    assert engine.lm_weight is not None
    assert engine.lm_weight.shape == (engine.cfg.d_model,
                                      engine.cfg.vocab_size)
    assert engine.head_compression > 1.0
    # slot storage is reused (reset, not reallocated) across lifetimes
    engine.submit([2], max_new_tokens=2)
    engine.run()
    assert engine.kv.resets >= 1
