"""Chunked batched prefill: token equivalence against the teacher-forcing
oracle (contiguous and paged, ragged prompt lengths), planner accounting,
typed budget rejection, TTFT decomposition, recorded fallbacks."""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.serve import (PagedKVCache, PrefillPlanner, RequestRejected,
                         ServeEngine, poisson_trace)


def _ragged_trace(cfg, n=5, seed=3):
    """Prompt lengths 1..13 — deliberately not multiples of the chunk
    (and including single-token prompts, which skip prefill entirely)."""
    return poisson_trace(n, rate=0.7, seed=seed, vocab_size=cfg.vocab_size,
                         prompt_len=(1, 13), max_new=(3, 8))


def _run_tokens(cfg, trace, *, sparsity=0.0, **engine_kw):
    eng = ServeEngine(cfg, num_slots=2, max_len=32, sparsity=sparsity,
                      seed=0, **engine_kw)
    reqs = [eng.submit(**spec) for spec in trace]
    eng.run()
    return [r.tokens for r in reqs], eng


# ------------------------------------------------------- equivalence -------


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-4b",
                                  "granite-moe-3b-a800m"])
@pytest.mark.parametrize("sparsity", [0.0, 0.75])
def test_prefill_matches_teacher_forcing(arch, sparsity):
    """Chunked prefill is token-identical to the legacy teacher-forced
    prompt walk on identical ragged traces — full attention, sliding
    windows and MoE, pruned or not, contiguous *and* paged.  The inner
    per-token write-then-attend scan sees exactly the cache state the
    decode path would, so equivalence is bit-level, not approximate."""
    cfg = get_smoke_config(arch)
    trace = _ragged_trace(cfg)
    base, _ = _run_tokens(cfg, trace, sparsity=sparsity)
    pf, eng = _run_tokens(cfg, trace, sparsity=sparsity, prefill_chunk=4)
    paged, _ = _run_tokens(cfg, trace, sparsity=sparsity, prefill_chunk=4,
                           paged=True, page_len=8)
    assert pf == base
    assert paged == base
    assert all(toks for toks in pf)
    rep = eng.report()["prefill"]
    assert rep["enabled"] and rep["calls"] > 0
    assert rep["tokens_prefilled"] == sum(
        len(t["prompt"]) - 1 for t in trace)


def test_chunk_wider_than_window_and_page():
    """A chunk that wraps a sliding-window ring *within one call* (and
    spans page boundaries) still matches the oracle: the inner scan
    overwrites ring lines in exactly decode's order."""
    cfg = get_smoke_config("gemma3-4b")        # window=8 local blocks
    trace = poisson_trace(4, rate=0.5, seed=5, vocab_size=cfg.vocab_size,
                          prompt_len=(18, 28), max_new=(3, 6))
    base, _ = _run_tokens(cfg, trace)
    wide, _ = _run_tokens(cfg, trace, prefill_chunk=16)
    wide_paged, _ = _run_tokens(cfg, trace, prefill_chunk=16, paged=True,
                                page_len=8)
    ragged_paged, _ = _run_tokens(cfg, trace, prefill_chunk=5, paged=True,
                                  page_len=8)
    assert wide == base and wide_paged == base and ragged_paged == base


def test_prefill_uses_fewer_engine_steps():
    """The point of the subsystem: a long prompt costs ceil((L-1)/C)
    chunk calls instead of L-1 full-batch decode steps."""
    cfg = get_smoke_config("olmo-1b")
    trace = [{"prompt": list(range(1, 26)), "max_new_tokens": 3,
              "arrival": 0.0}]
    base, beng = _run_tokens(cfg, trace)
    pf, peng = _run_tokens(cfg, trace, prefill_chunk=8)
    assert pf == base
    assert beng.report()["steps"] == 24 + 3        # 24 prompt walk + gen
    prep = peng.report()
    assert prep["prefill"]["calls"] == 3           # ceil(24 / 8)
    assert prep["steps"] < beng.report()["steps"]
    # decode ran only for real generation (plus admission-idle steps)
    assert prep["prefill"]["decode_steps"] < beng.report()["steps"]


# ------------------------------------------------------------ planner ------


def test_planner_chunks_ragged_prompts():
    p = PrefillPlanner(num_slots=3, chunk=4)
    assert not p.start(0, [7])                 # single token: no prefill
    assert p.start(1, list(range(10)))         # 9 positions -> 4+4+1
    assert p.start(2, list(range(6)))          # 5 positions -> 4+1
    tokens, pos, lens, done = p.next_call()
    assert tokens.shape == (3, 4)
    assert lens.tolist() == [0, 4, 4] and pos.tolist() == [0, 0, 0]
    assert done == []
    tokens, pos, lens, done = p.next_call()
    assert lens.tolist() == [0, 4, 1] and pos.tolist() == [0, 4, 4]
    assert done == [2] and p.in_prefill(1) and not p.in_prefill(2)
    tokens, pos, lens, done = p.next_call()
    assert lens.tolist() == [0, 1, 0] and done == [1]
    assert not p.has_work
    assert p.calls == 3 and p.tokens_prefilled == 9 + 5
    # a mid-prefill slot always parks on its next unwritten position
    p.start(0, list(range(7)))
    p.next_call()
    assert p.next_pos(0) == 4


def test_planner_batches_multiple_requests_per_call():
    p = PrefillPlanner(num_slots=4, chunk=8)
    for slot in range(4):
        assert p.start(slot, list(range(9)))
    _, _, lens, done = p.next_call()
    assert lens.tolist() == [8, 8, 8, 8]       # all four in one call
    assert done == [0, 1, 2, 3]
    assert p.report()["lane_utilization"] == 1.0


# ------------------------------------------------ admission / rejection ----


def test_nonpositive_budget_rejected_typed():
    """max_new_tokens < 1 used to quietly generate one token anyway (the
    budget check runs only after appending); now it is a typed reject
    and the engine keeps serving."""
    cfg = get_smoke_config("olmo-1b")
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0)
    with pytest.raises(RequestRejected):
        eng.submit([1, 2], max_new_tokens=0)
    with pytest.raises(RequestRejected):
        eng.submit([1, 2], max_new_tokens=-3)
    req = eng.submit([1, 2], max_new_tokens=1)
    eng.run()
    assert len(req.tokens) == 1


def test_recurrent_arch_falls_back_with_reason():
    cfg = get_smoke_config("rwkv6-3b")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = ServeEngine(cfg, num_slots=2, max_len=16, seed=0,
                          prefill_chunk=8)
    assert eng.prefill_chunk == 0
    assert "recurrent" in eng.prefill_fallback
    assert any("teacher-forcing" in str(w.message) for w in caught)
    req = eng.submit([1, 2, 3], max_new_tokens=3)
    eng.run()
    assert len(req.tokens) == 3
    rep = eng.report()["prefill"]
    assert rep["enabled"] is False and rep["fallback"]


# -------------------------------------------------------------- timing -----


@pytest.mark.parametrize("prefill_chunk", [0, 4])
def test_ttft_decomposes_into_components(prefill_chunk):
    """first_token_s = queue + prefill + first-decode for every done
    request, in both the chunked and the legacy teacher-forcing mode —
    prompt-walk time is no longer conflated with queueing."""
    cfg = get_smoke_config("olmo-1b")
    trace = _ragged_trace(cfg, n=4)
    eng = ServeEngine(cfg, num_slots=2, max_len=32, seed=0,
                      prefill_chunk=prefill_chunk)
    reqs = [eng.submit(**spec) for spec in trace]
    rep = eng.run()
    for r in reqs:
        for part in (r.queue_s, r.prefill_s, r.first_decode_s):
            assert part is not None and part >= 0
        assert r.queue_s + r.prefill_s + r.first_decode_s == pytest.approx(
            r.first_token_s, abs=1e-9)
    for key in ("queue_s", "prefill_s", "first_decode_s"):
        assert np.isfinite(rep["ttft"][key]["p50"])


# ----------------------------------------------------------- paging --------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.integers(1, 24)),
                min_size=1, max_size=6),
       st.sampled_from([4, 8]))
def test_ensure_range_equals_stepwise_ensure(ranges, page_len):
    """Bulk-mapping a chunk's pages is observationally identical to the
    per-position ensure walk the decode path does (same tables, same
    allocation counts) — for full-attention and ring pools alike."""
    cfg = get_smoke_config("gemma3-4b")
    bulk = PagedKVCache(cfg, num_slots=2, max_len=32, page_len=page_len)
    step = PagedKVCache(cfg, num_slots=2, max_len=32, page_len=page_len)
    for kv in (bulk, step):
        kv.reserve(32)
        kv.admit(0, 32)
    for start, n in ranges:
        bulk.ensure_range(0, start, start + n)
        for pos in range(start, start + n):
            step.ensure(0, pos)
        for b in bulk.pools:
            assert np.array_equal(bulk.pools[b].table, step.pools[b].table)
            assert bulk.pools[b].in_use == step.pools[b].in_use
            assert sorted(bulk.pools[b].free) == sorted(step.pools[b].free)
