"""SPMD correctness: sharded == single-device numerics (subprocess, 8 fake
devices), sharding-rule validity, HLO counters vs analytic ground truth."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch import sharding as shd
from repro.launch.hlo_counters import analyze
from repro.models.model import cache_structs, param_structs


def test_param_specs_are_valid_everywhere():
    """Every spec must divide its dim on the production mesh — the _fit
    fallback guarantees it; verify across all 10 archs."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch in ARCHS:
        cfg = get_config(arch)
        specs = shd.param_specs(cfg, FakeMesh())
        structs = param_structs(cfg)
        for (path, spec), leaf in zip(
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: hasattr(x, "index"))[0],
                jax.tree.leaves(structs)):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    assert dim % FakeMesh.shape[ax] == 0, (arch, path, spec)


def test_matrix_params_are_model_sharded():
    """TP must actually shard the big matrices (not fall back to full
    replication): for every arch, >60 % of matrix param bytes carry a
    'model' axis."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch in ARCHS:
        cfg = get_config(arch)
        specs = shd.param_specs(cfg, FakeMesh())
        structs = param_structs(cfg)
        tot, sharded = 0, 0
        for spec, leaf in zip(
                jax.tree.leaves(specs,
                                is_leaf=lambda x: hasattr(x, "index")),
                jax.tree.leaves(structs)):
            if len(leaf.shape) < 2:
                continue
            import math
            b = math.prod(leaf.shape)
            tot += b
            if "model" in tuple(spec):
                sharded += b
        assert sharded / tot > 0.6, (arch, sharded / tot)


def test_cache_specs_cover_all_entries():
    for arch in ("gemma3-12b", "jamba-v0.1-52b", "rwkv6-3b"):
        cfg = get_config(arch)

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        specs = shd.cache_specs(cfg, FakeMesh(), batch=128, max_len=1024)
        structs = cache_structs(cfg, 128, 1024)
        assert jax.tree.structure(
            jax.tree.map(lambda x: 0, specs,
                         is_leaf=lambda x: hasattr(x, "index"))
        ) == jax.tree.structure(jax.tree.map(lambda x: 0, structs))


_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp, json
    from repro.configs import get_smoke_config
    from repro.launch import sharding as shd
    from repro.launch.steps import build_train_step
    from repro.models import init_params
    from repro.train import optimizer as opt_lib

    cfg = get_smoke_config("%ARCH%")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (8, 16)),
                                   jnp.int32),
             "targets": jnp.asarray(r.integers(0, cfg.vocab_size, (8, 16)),
                                    jnp.int32)}
    step = build_train_step(cfg, opt_lib.OptConfig(lr=1e-3, warmup_steps=1))

    # single device
    p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

    # 2x4 (data, model) mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    psh = shd.named(mesh, shd.param_specs(cfg, mesh))
    osh = shd.named(mesh, shd.opt_specs(cfg, mesh))
    bspec = shd.batch_specs(cfg, mesh, 8)
    with mesh:
        pd = jax.device_put(params, psh)
        od = jax.device_put(opt_state, osh)
        bd = {k: jax.device_put(v, jax.NamedSharding(mesh, bspec(k)))
              for k, v in batch.items()}
        p2, o2, m2 = jax.jit(step, in_shardings=(psh, osh, None),
                             out_shardings=(psh, osh, None))(pd, od, bd)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                      "max_param_diff": diff}))
""")


@pytest.mark.parametrize("arch", ["olmo-1b", "moonshot-v1-16b-a3b",
                                  "jamba-v0.1-52b"])
def test_spmd_matches_single_device(arch):
    """DP2 x TP4 train step == single-device train step (numerics)."""
    script = _SPMD_SCRIPT.replace("%ARCH%", arch)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss1"] - res["loss2"]) < 1e-3, res
    assert res["max_param_diff"] < 5e-3, res


def test_hlo_counter_ground_truth():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["flops"] == pytest.approx(2 * 64**3 * 12)


def test_hlo_counter_collectives():
    """psum over a mesh axis shows up as all-reduce bytes x2 wire factor."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P
        from repro.launch.hlo_counters import analyze
        mesh = jax.make_mesh((8,), ("x",))
        def f(a):
            return jax.lax.psum(a, "x")
        from repro.compat import shard_map
        sf = shard_map(f, mesh=mesh, in_specs=P("x", None),
                       out_specs=P(None))
        c = jax.jit(sf).lower(
            jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
        r = analyze(c.as_text())
        print(json.dumps(r))
    """)
    import subprocess, sys, os
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300,
                         env={**os.environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r.get("all-reduce_bytes", 0) > 0
    assert r["wire_bytes"] == pytest.approx(2 * r["all-reduce_bytes"])
