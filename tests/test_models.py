"""Model stack: per-arch smoke, decode/forward consistency, layer math."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)
from repro.models.config import BlockCfg, ModelConfig
from repro.models.layers import moe_ffn, scan_attention
from repro.models.model import lm_head_weight
from repro.models import ssm
from repro.kernels import ref


def _smoke_batch(cfg, b, s, rng):
    batch = {}
    if cfg.frontend == "frames":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    elif cfg.frontend == "patches":
        fl = cfg.frontend_len
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, fl, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - fl)), jnp.int32)
        t = rng.integers(0, cfg.vocab_size, (b, s))
        t[:, :fl] = -1
        batch["targets"] = jnp.asarray(t, jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_loss(arch, rng):
    """Reduced config: one forward + loss on CPU, shape & finiteness."""
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, 2, 16, rng)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss)
    assert abs(float(loss) - math.log(cfg.vocab_size)) < 2.5


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch, rng):
    """One full train step (fwd+bwd+AdamW): params move, all finite."""
    from repro.launch.steps import build_train_step
    from repro.train import optimizer as opt_lib
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    batch = _smoke_batch(cfg, 2, 16, rng)
    step = build_train_step(cfg, opt_lib.OptConfig(lr=1e-3, warmup_steps=1,
                                                   total_steps=10))
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    """Token-by-token decode with caches must reproduce the parallel
    forward logits — validates KV caches, ring buffers and SSM states.
    f32 compute so bf16 reassociation noise doesn't mask cache bugs."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch),
                              compute_dtype="float32")
    params = init_params(jax.random.PRNGKey(1), cfg)
    b, t = 2, 12
    if cfg.frontend == "frames":
        embeds = jnp.asarray(rng.standard_normal((b, t, cfg.d_model)),
                             jnp.float32)
        hidden = forward(params, cfg, embeds=embeds)
    elif cfg.frontend == "patches":
        fl = cfg.frontend_len
        embeds = jnp.asarray(rng.standard_normal((b, fl, cfg.d_model)),
                             jnp.float32)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t - fl)),
                             jnp.int32)
        hidden = forward(params, cfg, tokens=tokens, embeds=embeds)
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)),
                             jnp.int32)
        hidden = forward(params, cfg, tokens=tokens)
    w = lm_head_weight(params, cfg).astype(hidden.dtype)
    ref_logits = np.asarray((hidden @ w).astype(jnp.float32))

    cache = init_cache(cfg, b, t)
    step = jax.jit(
        lambda p, c, tok, pos, emb: decode_step(p, c, cfg, tok, pos,
                                                embeds=emb),
        static_argnames=())
    got = []
    for pos in range(t):
        if cfg.frontend == "frames":
            tok, emb = None, embeds[:, pos:pos + 1]
        elif cfg.frontend == "patches":
            if pos < cfg.frontend_len:
                tok, emb = None, embeds[:, pos:pos + 1]
            else:
                tok, emb = tokens[:, pos - cfg.frontend_len:
                                  pos - cfg.frontend_len + 1], None
        else:
            tok, emb = tokens[:, pos:pos + 1], None
        logits, cache = decode_step(params, cache, cfg, tok,
                                    jnp.int32(pos), embeds=emb)
        got.append(np.asarray(logits))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, ref_logits, atol=2e-2, rtol=2e-2)


def test_scan_attention_matches_dense():
    r = np.random.default_rng(0)
    b, s, h, d = 2, 64, 4, 16
    q = jnp.asarray(r.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, s, 2, d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, s, 2, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    for window in (None, 16):
        out = scan_attention(q, k, v, pos, window=window, q_chunk=16,
                             kv_chunk=16)
        expect = ref.attention_ref(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=True, window=window).swapaxes(1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-3)


def test_moe_matches_dense_reference():
    """With generous capacity, sorted-dispatch MoE == explicit per-token
    top-k mixture."""
    cfg = ModelConfig(name="t", d_model=32, num_layers=1, num_heads=4,
                      num_kv_heads=4, d_ff=64, vocab_size=64,
                      pattern=(BlockCfg(ffn="moe"),), num_experts=4,
                      top_k=2, capacity_factor=8.0)
    r = np.random.default_rng(0)
    params = {
        "router": jnp.asarray(r.standard_normal((32, 4)) * 0.5, jnp.float32),
        "w_gate": jnp.asarray(r.standard_normal((4, 32, 64)) * 0.1),
        "w_up": jnp.asarray(r.standard_normal((4, 32, 64)) * 0.1),
        "w_down": jnp.asarray(r.standard_normal((4, 64, 32)) * 0.1),
    }
    x = jnp.asarray(r.standard_normal((2, 8, 32)), jnp.float32)
    got = moe_ffn(params, x, cfg)

    probs = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", x, params["router"]), -1)
    gate, idx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    expect = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        y = h @ params["w_down"][e]
        wsel = jnp.sum(jnp.where(idx == e, gate, 0.0), -1)
        expect += wsel[..., None] * y
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=1e-4)


def test_mamba_chunking_invariance():
    cfg = get_smoke_config("jamba-v0.1-52b")
    r = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a[0], params["blocks"]["b0"]["mamba"])
    x = jnp.asarray(r.standard_normal((2, 24, cfg.d_model)), jnp.float32)
    y1 = ssm.mamba_mix(p, x, cfg, chunk=4)
    y2 = ssm.mamba_mix(p, x, cfg, chunk=24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_rwkv_chunking_invariance():
    cfg = get_smoke_config("rwkv6-3b")
    r = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a[0], params["blocks"]["b0"]["rwkv"])
    x = jnp.asarray(r.standard_normal((2, 24, cfg.d_model)), jnp.float32)
    y1 = ssm.rwkv_mix(p, x, cfg, chunk=4)
    y2 = ssm.rwkv_mix(p, x, cfg, chunk=24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_scan_vs_unrolled_layers():
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              compute_dtype="float32")
    cfg_unroll = ModelConfig(**{**cfg.__dict__, "scan_layers": False,
                                "name": "u"})
    params = init_params(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(0)
    tokens = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    h1 = forward(params, cfg, tokens=tokens)
    h2 = forward(params, cfg_unroll, tokens=tokens)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


def test_param_counts_match_published_scale():
    """Full configs land near their nameplate parameter counts."""
    expect = {
        "gemma3-12b": (10e9, 14e9),
        "olmo-1b": (1.0e9, 1.4e9),
        "starcoder2-15b": (13e9, 17e9),
        "gemma3-4b": (3.5e9, 5.5e9),
        "rwkv6-3b": (2.5e9, 3.6e9),
        "jamba-v0.1-52b": (45e9, 58e9),
        # the assigned hyperparameters (48L, 64 experts, d_ff 1408) give
        # 27.7B total / 3.6B active: active matches the "a3b" moniker; the
        # "16b" nameplate would need fewer/narrower experts than assigned
        "moonshot-v1-16b-a3b": (25e9, 30e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        "internvl2-76b": (68e9, 82e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
