"""Paged KV cache: the reserved-bytes proof sweep.

Drives the continuous-batching engine over a (page_len × slots) grid on
a mixed-length Poisson trace — short and long requests interleaved, the
regime where the contiguous cache's worst-case ``num_slots × max_len``
reservation hurts most — once paged and once contiguous.  Each cell
reports:

* reserved KV-cache bytes, paged pool vs contiguous worst case (the
  paged pool is sized to the *trace's* worst per-request need, so the
  reduction is what right-sizing actually buys, with out-of-pages
  admissions queueing rather than crashing);
* measured tok/s for both engines on the identical trace (paging is
  token-lossless, so any delta is pure gather/scatter dispatch);
* allocator stats: peak pages, fragmentation of in-use pages.

``--out BENCH_serve.json`` merges a ``paging`` section into the existing
bench file (scripts/ci.sh runs a smoke cell every CI pass, after the
bitmap-streaming sweep writes the base file).
"""
from __future__ import annotations

import argparse

try:                                    # script: benchmarks/ on sys.path
    from _bench_io import bench_timer, merge_section
except ImportError:                     # package: imported from repo root
    from benchmarks._bench_io import bench_timer, merge_section

from repro.configs import get_config, get_smoke_config
from repro.serve import ServeEngine, poisson_trace


def _trace(cfg, requests, rate, max_len, seed):
    """Mixed-length trace: prompts 1..4, budgets 1..24 tokens — the
    serving regime where a long-capacity engine (``max_len`` is the
    *ceiling*, not the typical request) pays worst-case contiguous
    reservation for mostly-short traffic."""
    hi = max(2, min(24, max_len - 4))
    return poisson_trace(requests, rate=rate, seed=seed,
                         vocab_size=cfg.vocab_size, prompt_len=(1, 4),
                         max_new=(1, hi))


def _run(cfg, trace, *, slots, max_len, sparsity, seed, paged,
         page_len=0, pool_tokens=None):
    eng = ServeEngine(cfg, num_slots=slots, max_len=max_len,
                      sparsity=sparsity, seed=seed, paged=paged,
                      page_len=page_len, page_pool_tokens=pool_tokens,
                      head_sparsity=0.0)
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)
        return eng.run()


def sweep(arch: str = "olmo-1b", smoke: bool = True,
          page_lens=(8, 16), slots_list=(2, 4), requests: int = 12,
          rate: float = 0.7, max_len: int = 256, sparsity: float = 0.5,
          seed: int = 0, repeats: int = 3, verbose: bool = True) -> dict:
    """(page_len × slots) grid, paged vs contiguous on identical traces.

    The paged pool is budgeted to ``slots ×`` the trace's worst single
    request (rounded up to pages) — enough that admission never queues
    on slot-count alone, small enough that reserved bytes track live
    tokens instead of ``slots × max_len``."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    rows = []
    for slots in slots_list:
        trace = _trace(cfg, requests, rate, max_len, seed)
        worst = max(len(t["prompt"]) + t["max_new_tokens"] - 1
                    for t in trace)
        cont = max(
            (_run(cfg, trace, slots=slots, max_len=max_len,
                  sparsity=sparsity, seed=seed, paged=False)
             for _ in range(repeats)), key=lambda r: r["tok_per_s"])
        for page_len in page_lens:
            pool_tokens = slots * (-(-worst // page_len)) * page_len
            paged = max(
                (_run(cfg, trace, slots=slots, max_len=max_len,
                      sparsity=sparsity, seed=seed, paged=True,
                      page_len=page_len, pool_tokens=pool_tokens)
                 for _ in range(repeats)), key=lambda r: r["tok_per_s"])
            pg = paged["paging"]
            row = {
                "arch": arch, "slots": slots, "page_len": page_len,
                "max_len": max_len, "trace_worst_need": worst,
                "pool_tokens": pool_tokens,
                "tok_per_s": paged["tok_per_s"],
                "tok_per_s_contiguous": cont["tok_per_s"],
                "tok_per_s_ratio": paged["tok_per_s"] / cont["tok_per_s"],
                "reserved_kv_bytes": pg["reserved_kv_bytes"],
                "contiguous_kv_bytes": cont["paging"]["reserved_kv_bytes"],
                "reserved_reduction": (
                    cont["paging"]["reserved_kv_bytes"]
                    / pg["reserved_kv_bytes"]),
                "pages_peak": pg["pages_peak"],
                "pages_total": pg["pages_total"],
            }
            rows.append(row)
            if verbose:
                print(f"  {arch:10s} slots={slots} page_len={page_len:3d}"
                      f" | {row['tok_per_s']:8.1f} tok/s (contiguous "
                      f"{row['tok_per_s_contiguous']:8.1f}, "
                      f"{row['tok_per_s_ratio']:.2f}x) | reserved KV "
                      f"{row['reserved_kv_bytes']/1e3:7.1f}kB vs "
                      f"{row['contiguous_kv_bytes']/1e3:7.1f}kB "
                      f"({row['reserved_reduction']:.2f}x) | pages "
                      f"{row['pages_peak']}/{row['pages_total']}")
    headline = {
        "arch": arch,
        "reserved_reduction_min": min(r["reserved_reduction"]
                                      for r in rows),
        "tok_per_s_ratio_worst": min(r["tok_per_s_ratio"] for r in rows),
    }
    if verbose:
        print(f"  headline: >= {headline['reserved_reduction_min']:.2f}x "
              f"less KV reserved than slots x max_len; paged/contiguous "
              f"tok/s worst {headline['tok_per_s_ratio_worst']:.2f}")
    return {"rows": rows, "headline": headline}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--page-lens", type=int, nargs="+", default=[8, 16])
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=0.7)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="merge a 'paging' section into this JSON file "
                         "(e.g. BENCH_serve.json)")
    args = ap.parse_args()
    with bench_timer("paging") as timing:
        result = sweep(args.arch, smoke=args.smoke,
                       page_lens=tuple(args.page_lens),
                       slots_list=tuple(args.slots),
                       requests=args.requests, rate=args.rate,
                       max_len=args.max_len, sparsity=args.sparsity,
                       seed=args.seed, repeats=args.repeats)
    if args.out:
        merge_section(args.out, "paging", result, wall_s=timing.wall_s)


if __name__ == "__main__":
    main()
