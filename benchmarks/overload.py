"""Overload behaviour: goodput under an over-subscribed Poisson trace.

Drives the engine with an arrival rate well past what its slots can
drain, twice on the same seeded trace:

* **baseline** — no admission control: every request queues, TTFT and
  latency grow without bound as the backlog builds, and every request
  eventually completes (late);
* **shedding** — ``max_queue`` bounds the due queue: excess arrivals
  end SHED with a typed ``ServeOverloaded`` (recorded, not fatal), and
  the requests that *are* admitted see bounded queues.

Each cell reports goodput (tokens delivered by requests that finished
within ``--slo-ms`` of coming due), the shed rate, latency / TTFT
percentiles, and wasted tokens.  The point of the comparison: under
overload, shedding trades completed-late tokens for within-SLO tokens —
goodput (not raw throughput) is the served-system metric.

``--out BENCH_serve.json`` merges an ``overload`` section into the
bench file, preserving the other tools' sections.
"""
from __future__ import annotations

import argparse

try:                                    # script: benchmarks/ on sys.path
    from _bench_io import bench_timer, merge_section
except ImportError:                     # package: imported from repo root
    from benchmarks._bench_io import bench_timer, merge_section

from repro.configs import get_config, get_smoke_config
from repro.serve import RequestState, ServeEngine, poisson_trace


def _cell(cfg, *, slots: int, requests: int, rate: float, max_len: int,
          sparsity: float, seed: int, slo_ms: float,
          max_queue: int | None) -> dict:
    eng = ServeEngine(cfg, num_slots=slots, max_len=max_len,
                      sparsity=sparsity, seed=seed,
                      max_queue=max_queue)
    hi = max(1, min(12, max_len - 4))
    trace = poisson_trace(requests, rate=rate, seed=seed,
                          vocab_size=cfg.vocab_size, prompt_len=(1, 4),
                          max_new=(max(1, hi // 2), hi))
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)   # future arrivals: due-time shedding
        rep = eng.run()
    good = wasted = done = 0
    lat = []
    for r in eng.requests:
        if r.state is RequestState.DONE:
            done += 1
            lat.append(r.latency_s)
            if r.latency_s is not None and r.latency_s * 1e3 <= slo_ms:
                good += len(r.tokens)
            else:
                wasted += len(r.tokens)   # delivered, but past the SLO
    lc = rep["lifecycle"]
    dt = rep["wall_s"]
    return {
        "max_queue": max_queue,
        "requests": requests,
        "completed": done,
        "shed": lc["shed"],
        "shed_rate": lc["shed"] / requests,
        "generated_tokens": rep["generated_tokens"],
        "goodput_tok_per_s": good / dt if dt > 0 else None,
        "tok_per_s": rep["tok_per_s"],
        "within_slo_tokens": good,
        "late_tokens": wasted,
        "wasted_tokens": lc["wasted_tokens"],
        "latency_s": rep["latency_s"],
        "first_token_s": rep["first_token_s"],
    }


def sweep(arch: str = "olmo-1b", smoke: bool = True, slots: int = 2,
          requests: int = 24, rate: float = 4.0, max_len: int = 48,
          sparsity: float = 0.5, seed: int = 0, slo_ms: float = 200.0,
          max_queue: int = 4, verbose: bool = True) -> dict:
    """Baseline vs shedding on the same over-subscribed seeded trace."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    cells = {}
    for name, mq in (("baseline", None), ("shedding", max_queue)):
        cells[name] = _cell(cfg, slots=slots, requests=requests,
                            rate=rate, max_len=max_len, sparsity=sparsity,
                            seed=seed, slo_ms=slo_ms, max_queue=mq)
        if verbose:
            c = cells[name]
            gp = c["goodput_tok_per_s"]
            print(f"[{name:>8}] {c['completed']}/{requests} done, "
                  f"{c['shed']} shed ({c['shed_rate']:.0%}) | goodput "
                  f"{gp:.1f} tok/s (raw {c['tok_per_s']:.1f}) | "
                  f"p99 latency {c['latency_s']['p99'] * 1e3:.0f}ms"
                  if gp is not None else f"[{name:>8}] no cells")
    result = {"arch": arch, "slots": slots, "rate": rate,
              "slo_ms": slo_ms, "seed": seed, "cells": cells}
    if verbose:
        b, s = cells["baseline"], cells["shedding"]
        if b["goodput_tok_per_s"] and s["goodput_tok_per_s"]:
            print(f"goodput ratio shedding/baseline: "
                  f"{s['goodput_tok_per_s'] / b['goodput_tok_per_s']:.2f}x"
                  f" at {s['shed_rate']:.0%} shed")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean arrivals per decode step — deliberately "
                         "past what the slots can drain")
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--slo-ms", type=float, default=200.0,
                    help="end-to-end latency SLO defining goodput")
    ap.add_argument("--max-queue", type=int, default=4,
                    help="shedding cell's due-queue bound")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="merge an 'overload' section into this JSON "
                         "file (e.g. BENCH_serve.json)")
    args = ap.parse_args()
    with bench_timer("overload") as timing:
        result = sweep(args.arch, smoke=args.smoke, slots=args.slots,
                       requests=args.requests, rate=args.rate,
                       max_len=args.max_len, sparsity=args.sparsity,
                       slo_ms=args.slo_ms, max_queue=args.max_queue,
                       seed=args.seed)
    if args.out:
        merge_section(args.out, "overload", result, wall_s=timing.wall_s)


if __name__ == "__main__":
    main()
