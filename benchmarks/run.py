"""Benchmark entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus the per-benchmark
summaries.  ``python -m benchmarks.run [--fast]``.
"""
from __future__ import annotations

import argparse
import sys
import time


def _csv(name: str, us: float, derived: str):
    print(f"CSV,{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sampling for quick regression runs")
    args = ap.parse_args()

    sys.path.insert(0, "src")
    from benchmarks import (comparison_table, kernel_bench, mobilenet_pw,
                            roofline, sparse_serving, sparsity_sweep)

    print("== [1/6] MobileNetV2 PW layers (paper Fig. 6 / §III-A) ==")
    t0 = time.time()
    _, s1 = mobilenet_pw.run(max_row_tiles=2 if args.fast else 8,
                             verbose=not args.fast)
    _csv("mobilenet_pw", (time.time() - t0) * 1e6,
         f"mapm={s1['avg_mapm_byte_per_mac']:.3f};"
         f"util={s1['overall_utilization']:.3f};"
         f"speedup={s1['overall_speedup']:.2f};"
         f"sram_cut={s1['sram_reduction_vs_sparten']:.3f}")
    for k, v in s1.items():
        print(f"  {k:30s} {v:.4f}")

    print("\n== [2/6] Random-matrix sparsity sweep (paper Fig. 7) ==")
    t0 = time.time()
    _, s2 = sparsity_sweep.run(size=256 if args.fast else 1024,
                               max_row_tiles=2 if args.fast else 4,
                               verbose=not args.fast)
    _csv("sparsity_sweep", (time.time() - t0) * 1e6,
         f"min_util_mid={s2['mid_range_min_utilization']:.3f}")
    for k, v in s2.items():
        print(f"  {k:30s} {v:.4f}")

    print("\n== [3/6] Comparison table + breakdowns (Table I, Fig. 8/9) ==")
    t0 = time.time()
    _, s3 = comparison_table.run()
    _csv("comparison_table", (time.time() - t0) * 1e6,
         f"tops_w={s3['ours_tops_per_watt']:.3f};"
         f"vs_sparten={s3['vs_sparten_style_energy_ratio']:.2f}x")

    print("\n== [4/6] Kernel HBM-traffic microbench (TPU adaptation) ==")
    t0 = time.time()
    kernel_bench.run()
    _csv("kernel_bench", (time.time() - t0) * 1e6, "see rows above")

    print("\n== [5/6] Roofline from dry-run artifacts ==")
    t0 = time.time()
    roofline.main([])
    _csv("roofline", (time.time() - t0) * 1e6, "see table above")

    print("\n== [6/6] Sparse serving (paper technique on decode) ==")
    t0 = time.time()
    sparse_serving.main()
    _csv("sparse_serving", (time.time() - t0) * 1e6, "see rows above")


if __name__ == "__main__":
    main()
