"""TPU-adaptation microbench: HBM-traffic model + interpret-mode checks.

No TPU in this container, so the kernel "benchmark" is the structural one
the roofline uses: analytic HBM bytes of bitmap_spmm vs its dense
equivalent across sparsities (the MAPM analogue), plus wall-clock of the
XLA reference paths (the lowered CPU path) for regression tracking.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.bitmap_spmm import hbm_traffic_model
from repro.sparse import pack_bitmap, pack_block_sparse


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters * 1e6  # us


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    m = k = n = 512
    rows = []
    for sparsity in (0.5, 0.75, 0.9):
        w = rng.standard_normal((k, n)).astype(np.float32)
        w *= rng.random((k, n)) >= sparsity
        bw = pack_bitmap(w, block=(128, 128))
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        t = hbm_traffic_model((m, k), bw)
        us = _time(lambda xx: ref.bitmap_spmm_ref(xx, bw), x)
        rows.append({
            "kernel": "bitmap_spmm", "sparsity": sparsity,
            "weight_compression": t["weight_compression"],
            "hbm_reduction": 1 - t["sparse_bytes"] / t["dense_bytes"],
            "xla_ref_us": us,
        })
        if verbose:
            r = rows[-1]
            print(f"  bitmap_spmm s={sparsity:.2f} "
                  f"weight_compression={r['weight_compression']:.2f}x "
                  f"hbm_total_reduction={r['hbm_reduction']:.1%} "
                  f"ref={us:.0f}us", flush=True)

    # block-sparse: compute skipped entirely for zero blocks
    for p_zero in (0.5, 0.75):
        w = rng.standard_normal((k, n)).astype(np.float32)
        mask = rng.random((k // 128, n // 128)) >= p_zero
        w = (w.reshape(k // 128, 128, n // 128, 128)
             * mask[:, None, :, None]).reshape(k, n)
        bw = pack_block_sparse(w, block=(128, 128))
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        us = _time(lambda xx: ref.block_sparse_matmul_ref(xx, bw), x)
        rows.append({
            "kernel": "block_sparse", "sparsity": p_zero,
            "block_density": bw.density,
            "flop_reduction": 1 - bw.density,
            "xla_ref_us": us,
        })
        if verbose:
            r = rows[-1]
            print(f"  block_sparse p0={p_zero:.2f} "
                  f"density={r['block_density']:.2f} "
                  f"flop_reduction={r['flop_reduction']:.1%} "
                  f"ref={us:.0f}us", flush=True)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
