"""Chunked batched prefill: the TTFT proof sweep.

Drives the continuous-batching engine over a ``prefill_chunk`` sweep on
a *long-prompt* Poisson trace — the regime the teacher-forcing admission
path is worst at, since every prompt token used to cost one full-batch
decode step of latency before the first generated token.  Each cell
reports, against the ``prefill_chunk=0`` teacher-forcing baseline on the
identical trace:

* TTFT p50/p99 (arrival -> first generated token) plus the engine's
  queue / prefill / first-decode decomposition — chunked prefill is
  token-lossless, so any TTFT delta is pure admission mechanics;
* measured tok/s (generated tokens over the whole run) — the chunk
  calls replace prompt-walk decode steps, so throughput should not
  regress while TTFT drops;
* chunk-call accounting: calls, engine steps prefill vs decode, lane
  utilization of the padded (slots × chunk) call batch.

``--out BENCH_serve.json`` merges a ``prefill`` section into the
existing bench file (scripts/ci.sh runs a smoke cell every CI pass).
"""
from __future__ import annotations

import argparse

try:                                    # script: benchmarks/ on sys.path
    from _bench_io import bench_timer, merge_section
except ImportError:                     # package: imported from repo root
    from benchmarks._bench_io import bench_timer, merge_section

from repro.configs import get_config, get_smoke_config
from repro.serve import ServeEngine, poisson_trace


def _trace(cfg, requests, rate, max_len, seed):
    """Long prompts, short generations: prompts 3/4 of max_len, budgets
    a handful of tokens — TTFT dominated by prompt ingestion."""
    plo = max(2, max_len // 2)
    phi = max(plo, 3 * max_len // 4)
    hi = max(2, min(8, max_len - phi))
    return poisson_trace(requests, rate=rate, seed=seed,
                         vocab_size=cfg.vocab_size, prompt_len=(plo, phi),
                         max_new=(1, hi))


def _run(cfg, trace, *, slots, max_len, sparsity, seed, prefill_chunk,
         paged=False, page_len=16):
    eng = ServeEngine(cfg, num_slots=slots, max_len=max_len,
                      sparsity=sparsity, seed=seed, head_sparsity=0.0,
                      prefill_chunk=prefill_chunk, paged=paged,
                      page_len=page_len)
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)
        return eng.run()


def sweep(arch: str = "olmo-1b", smoke: bool = True,
          chunks=(8, 16), slots: int = 4, requests: int = 8,
          rate: float = 0.3, max_len: int = 96, sparsity: float = 0.5,
          paged: bool = False, seed: int = 0, repeats: int = 3,
          verbose: bool = True) -> dict:
    """``prefill_chunk`` sweep vs the teacher-forcing baseline on one
    identical long-prompt trace (tokens are bit-identical across the
    whole row — the sweep measures admission latency, nothing else).

    Each cell keeps the best-TTFT run of ``repeats`` (smoke cells finish
    in well under a second, so single runs are scheduler-noise-bound).
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    trace = _trace(cfg, requests, rate, max_len, seed)
    mean_prompt = sum(len(t["prompt"]) for t in trace) / len(trace)

    def best(chunk):
        return min((_run(cfg, trace, slots=slots, max_len=max_len,
                         sparsity=sparsity, seed=seed, prefill_chunk=chunk,
                         paged=paged)
                    for _ in range(repeats)),
                   key=lambda r: r["first_token_s"]["p50"])

    base = best(0)
    rows = []
    for chunk in chunks:
        rep = best(chunk)
        pf, tt = rep["prefill"], rep["ttft"]
        row = {
            "arch": arch, "slots": slots, "prefill_chunk": chunk,
            "mean_prompt_len": mean_prompt, "paged": paged,
            "ttft_p50_s": rep["first_token_s"]["p50"],
            "ttft_p99_s": rep["first_token_s"]["p99"],
            "ttft_p50_baseline_s": base["first_token_s"]["p50"],
            "ttft_p50_speedup": (base["first_token_s"]["p50"]
                                 / rep["first_token_s"]["p50"]),
            "ttft_split_p50_s": {k: tt[k]["p50"] for k in tt},
            "tok_per_s": rep["tok_per_s"],
            "tok_per_s_baseline": base["tok_per_s"],
            "tok_per_s_ratio": rep["tok_per_s"] / base["tok_per_s"],
            "chunk_calls": pf["calls"],
            "prefill_steps": pf["prefill_steps"],
            "decode_steps": pf["decode_steps"],
            "baseline_steps": base["steps"],
            "lane_utilization": pf["lane_utilization"],
        }
        rows.append(row)
        if verbose:
            print(f"  {arch:10s} slots={slots} chunk={chunk:3d} | TTFT "
                  f"p50 {row['ttft_p50_s'] * 1e3:7.1f}ms vs baseline "
                  f"{row['ttft_p50_baseline_s'] * 1e3:7.1f}ms "
                  f"({row['ttft_p50_speedup']:.2f}x) | "
                  f"{row['tok_per_s']:7.1f} tok/s "
                  f"({row['tok_per_s_ratio']:.2f}x) | "
                  f"{row['chunk_calls']} calls, lanes "
                  f"{row['lane_utilization']:.0%}")
    headline = {
        "arch": arch,
        "mean_prompt_len": mean_prompt,
        "ttft_p50_speedup_best": max(r["ttft_p50_speedup"] for r in rows),
        "tok_per_s_ratio_worst": min(r["tok_per_s_ratio"] for r in rows),
    }
    if verbose:
        print(f"  headline: TTFT p50 {headline['ttft_p50_speedup_best']:.2f}x"
              f" faster than teacher-forcing on ~{mean_prompt:.0f}-token "
              f"prompts; tok/s worst ratio "
              f"{headline['tok_per_s_ratio_worst']:.2f}")
    return {"rows": rows, "headline": headline}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--chunks", type=int, nargs="+", default=[8, 16])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.3)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--paged", action="store_true",
                    help="run the sweep on the paged KV cache (prefill "
                         "bulk-maps each chunk's pages)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="merge a 'prefill' section into this JSON file "
                         "(e.g. BENCH_serve.json)")
    args = ap.parse_args()
    with bench_timer("prefill") as timing:
        result = sweep(args.arch, smoke=args.smoke,
                       chunks=tuple(args.chunks), slots=args.slots,
                       requests=args.requests, rate=args.rate,
                       max_len=args.max_len, sparsity=args.sparsity,
                       paged=args.paged, seed=args.seed,
                       repeats=args.repeats)
    if args.out:
        merge_section(args.out, "prefill", result, wall_s=timing.wall_s)


if __name__ == "__main__":
    main()
