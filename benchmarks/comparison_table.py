"""Paper Table I + Figs. 8-9: cross-design comparison under the 28 nm
event-level energy model, plus our power/area-proxy breakdown.

Baseline MAPMs are the paper's measured values (SparTen 2.09, SCNN 2.03);
our MAPM/utilisation come from the simulator on the MobileNetV2 PW workload.
TOPS counts non-zero ops only (SIGMA's accounting, as the paper adopts).
"""
from __future__ import annotations

import numpy as np

from repro.core.accelerator import AcceleratorConfig, run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.core.energy import (CLOCK_HZ, NUM_MACS, energy_dataflow,
                               energy_from_stats, power_watts, tops_per_watt)
from repro.core.mapm import SCNN_PAPER_MAPM, SPARTEN_PAPER_MAPM

PAPER_TABLE = {  # published numbers for context (Table I)
    "SparTen[2]": {"tops_w": 0.43, "note": "45nm, 32 MACs, output reuse"},
    "Eyeriss v2[7]": {"tops_w": 0.251, "note": "65nm, incl. zero ops"},
    "SIGMA[8]": {"tops_w": 0.48, "note": "28nm, 16384 MACs"},
    "SNAP[9]": {"tops_w": 0.25, "note": "65nm, 100% util assumed"},
    "ORSAS[10]": {"tops_w": 0.52, "note": "55nm, 100% util assumed"},
    "paper (ours)": {"tops_w": 1.198, "note": "28nm, 256 MACs"},
}


def run(seed: int = 0, verbose: bool = True):
    rng = np.random.default_rng(seed)
    x = random_sparse((512, 1024), 0.45, rng)
    w = prune_global_l1(rng.standard_normal((512, 1024)).astype(np.float32),
                        0.75)
    rep = run_gemm(x, w, AcceleratorConfig(), max_row_tiles=8, seed=seed)
    macs, cycles = rep.stats.macs, rep.stats.cycles

    ours = energy_from_stats(rep.stats)
    rows = {
        "ours (SIDR+EIM)": {
            "mapm": rep.mapm,
            "energy_j": ours.total_j,
            "tops_w": tops_per_watt(macs, ours.total_j),
            "power_w": power_watts(ours.total_j, cycles),
        }
    }
    # baseline dataflows on the identical workload, identical MAC count
    for name, mapm, util in (("SparTen-style", SPARTEN_PAPER_MAPM, 0.35),
                             ("SCNN-style", SCNN_PAPER_MAPM, 0.5)):
        cyc = int(macs / (util * NUM_MACS))
        e = energy_dataflow(macs, mapm * macs, cyc)
        rows[name] = {"mapm": mapm, "energy_j": e,
                      "tops_w": tops_per_watt(macs, e),
                      "power_w": power_watts(e, cyc)}

    summary = {
        "ours_tops_per_watt": rows["ours (SIDR+EIM)"]["tops_w"],
        "vs_sparten_style_energy_ratio":
            rows["SparTen-style"]["energy_j"] / rows["ours (SIDR+EIM)"][
                "energy_j"],
        "vs_scnn_style_energy_ratio":
            rows["SCNN-style"]["energy_j"] / rows["ours (SIDR+EIM)"][
                "energy_j"],
        "paper_gain_vs_sota": 2.5,
        "power_breakdown": ours.breakdown(),
        "throughput_tops": 2 * macs / (cycles / CLOCK_HZ) / 1e12,
        "paper_throughput_tops": 0.27,
    }
    if verbose:
        print("== Table I reproduction (modelled, identical workload) ==")
        for name, r in rows.items():
            print(f"  {name:16s} mapm={r['mapm']:.3f} "
                  f"tops/w={r['tops_w']:.3f} power={r['power_w']*1e3:.0f}mW")
        print("  published:", {k: v["tops_w"] for k, v in
                               PAPER_TABLE.items()})
        print("  power breakdown (Fig. 8):",
              {k: f"{v:.0%}" for k, v in summary["power_breakdown"].items()})
    return rows, summary


def main():
    run()


if __name__ == "__main__":
    main()
