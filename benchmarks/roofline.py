"""Roofline report (deliverable g): three terms per (arch × shape × mesh).

Reads the dry-run JSONs and derives, per device:
    compute    = HLO_FLOPs / 197 TFLOP/s
    memory     = HLO_bytes / 819 GB/s
    collective = wire_bytes / (4 × 50 GB/s ICI links)
plus MODEL_FLOPS (6·N·D train / 2·N·D inference, N_active for MoE), the
useful-compute ratio, the dominant term, and a one-line "what would move
it".  Emits the markdown table EXPERIMENTS.md §Roofline embeds.

With ``--serve-artifacts``, it additionally consumes serving traffic
artifacts (``--traffic-out`` JSONs): each artifact carries per-phase
roofline terms measured from the engine's traffic ledger, and the rows
are merged as a ``roofline`` section into ``BENCH_serve.json`` via
``_bench_io`` — the serving-side counterpart of the dry-run table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs import SHAPES
from repro.launch.hlo_analysis import roofline

try:
    from _bench_io import bench_timer, merge_section
except ImportError:                                    # package import
    from benchmarks._bench_io import bench_timer, merge_section


def model_flops_per_device(rec: Dict) -> float:
    shape = SHAPES[rec["shape"]]
    n_active = rec["active_param_count"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / rec["num_devices"]


def advice(rec: Dict, terms: Dict) -> str:
    b = terms["bottleneck"]
    kind = SHAPES[rec["shape"]].kind
    if b == "compute":
        ratio = model_flops_per_device(rec) / max(rec["flops_per_device"], 1)
        if ratio < 0.5:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute / attention waste")
        return "compute-bound near useful-FLOP limit: healthy"
    if b == "memory":
        if kind == "decode":
            return ("decode weight streaming: compress weights (bitmap "
                    "kernel) or raise batch to amortise")
        return "reduce activation traffic: fuse, recompute less, bf16 stats"
    return "collective-bound: reshard to cut all-reduce volume / overlap"


def load_records(dryrun_dir: str = "results/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def build_rows(recs: List[Dict]) -> List[Dict]:
    rows = []
    for rec in recs:
        terms = roofline(rec["flops_per_device"],
                         rec["hbm_bytes_per_device"],
                         rec["collectives"].get("wire_bytes", 0.0))
        mf = model_flops_per_device(rec)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": terms["compute_s"],
            "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "bottleneck": terms["bottleneck"],
            "model_flops_per_dev": mf,
            "useful_ratio": mf / max(rec["flops_per_device"], 1.0),
            "step_s": terms["step_time_overlapped_s"],
            # usable fraction of peak compute in the overlapped-ideal step
            "mfu_bound": (mf / 197e12) / max(
                terms["step_time_overlapped_s"], 1e-30),
            "advice": advice(rec, terms),
        })
    return rows


def markdown_table(rows: List[Dict], mesh_filter: str = "16x16") -> str:
    out = ["| arch | shape | compute s | memory s | coll s | bound | "
           "useful | MFU-bound | what moves it |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh_filter:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']:.2f} | {r['advice']} |")
    return "\n".join(out)


def serve_roofline_rows(paths: List[str]) -> List[Dict]:
    """Per-(arch × phase) roofline rows from serving traffic artifacts.

    The artifact's roofline terms are ledger-measured (bytes per decode
    step / prefill call actually accounted during the run), so these
    rows reflect serving reality rather than a dry-run lowering."""
    rows = []
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != "repro.serve.traffic/v1":
            raise ValueError(f"{path}: not a traffic artifact "
                             f"(schema={doc.get('schema')!r})")
        tr = doc["traffic"]
        cx = tr.get("crosscheck") or {}
        for phase, terms in tr["roofline"].items():
            row = {
                "arch": doc["arch"], "phase": phase,
                "sparsity": doc.get("sparsity", 0.0),
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "bottleneck": terms["bottleneck"],
                "step_s": terms["step_time_overlapped_s"],
                "weight_sparse_bytes_per_step":
                    tr["weight"]["sparse_bytes_per_step"],
                "pj_per_token": tr["energy"]["pj_per_token"],
                "tops_per_watt": tr["energy"]["tops_per_watt"],
            }
            if phase in cx:
                row["modeled_vs_compiled_ratio"] = cx[phase]["ratio"]
            rows.append(row)
    return rows


def serve_main(paths: List[str], out: str) -> None:
    with bench_timer("roofline") as t:
        rows = serve_roofline_rows(paths)
    result = {"rows": rows,
              "phases": sorted({r["phase"] for r in rows}),
              "archs": sorted({r["arch"] for r in rows})}
    merge_section(out, "roofline", result, wall_s=t.wall_s)
    for r in rows:
        print(f"  {r['arch']:<24s} {r['phase']:<8s} "
              f"{r['bottleneck']}-bound "
              f"(compute {r['compute_s'] * 1e6:.2f}us / memory "
              f"{r['memory_s'] * 1e6:.2f}us)")


def main(argv: "List[str] | None" = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--serve-artifacts", nargs="+", default=None,
                    help="serving traffic artifacts (--traffic-out "
                         "JSONs); merges a per-phase roofline section "
                         "into the serve benchmark JSON instead of "
                         "reading dry-run records")
    ap.add_argument("--out", default="benchmarks/BENCH_serve.json",
                    help="benchmark JSON to merge the serving roofline "
                         "section into")
    args = ap.parse_args(argv)
    if args.serve_artifacts:
        serve_main(args.serve_artifacts, args.out)
        return
    recs = load_records()
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first")
        return
    rows = build_rows(recs)
    print(markdown_table(rows))
    print()
    # summary of bottleneck distribution
    from collections import Counter
    c = Counter(r["bottleneck"] for r in rows if r["mesh"] == "16x16")
    print("bottleneck distribution (single pod):", dict(c))
    worst = sorted((r for r in rows if r["mesh"] == "16x16"),
                   key=lambda r: r["mfu_bound"])[:3]
    print("worst MFU-bound cells:",
          [(r["arch"], r["shape"], round(r["mfu_bound"], 3))
           for r in worst])


if __name__ == "__main__":
    main()
