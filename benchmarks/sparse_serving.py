"""Paper-technique serving analysis: bitmap-compressed weights on decode.

Decode is memory-bound (§Roofline): the step time is HBM traffic / BW.
This benchmark splits each decode cell's measured per-device traffic into
weight-streaming vs everything else (KV cache, activations) and applies
the *measured* bitmap-format compression (pack_bitmap at the paper's 75 %
global-L1 sparsity, including bitmap + row-offset overhead — the same
format the validated ``bitmap_spmm`` kernel consumes) to the weight term.

This is the TPU analogue of the paper's headline (86 % SRAM-access cut →
2.5× power efficiency): HBM-traffic cut → decode-step speed-up, largest
where weight streaming dominates (small batch / long context).
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import HBM_BW
from repro.sparse.format import pack_bitmap
from repro.sparse.pruning import per_tensor_prune


def measured_compression(sparsity: float = 0.75, seed: int = 0) -> float:
    """Bitmap-format compression at the paper's sparsity, with overheads."""
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((1024, 1024)), jnp.bfloat16)
    wp = per_tensor_prune(w, sparsity)
    return pack_bitmap(np.asarray(wp, np.float32).astype(np.float16),
                       block=(128, 128)).compression


def matmul_weight_bytes_per_device(arch: str, n_model_shards: int = 16,
                                   itemsize: int = 2) -> float:
    """Streamed matmul weights per decode step per device (embeddings are
    gathered, not streamed; tied LM head is streamed)."""
    cfg = get_config(arch)
    from repro.models.model import param_shapes
    total = 0
    for path, shape in _walk(param_shapes(cfg)):
        if "embed" in path and cfg.tie_embeddings:
            total += math.prod(shape)      # tied head is streamed
        elif "embed" in path:
            continue
        elif len(shape) >= 2:
            total += math.prod(shape)
    return total * itemsize / n_model_shards


def _walk(d, prefix=""):
    for k, v in d.items():
        p = f"{prefix}/{k}"
        if isinstance(v, dict):
            yield from _walk(v, p)
        else:
            yield p, v


def run(cells=(("gemma3-12b", "decode_2k_b8"),
              ("internvl2-76b", "decode_2k_b8"),
              ("gemma3-12b", "decode_32k"), ("gemma3-12b", "long_500k"),
              ("internvl2-76b", "decode_32k"), ("rwkv6-3b", "long_500k")),
        dryrun_dir: str = "results/dryrun", sparsity: float = 0.75,
        verbose: bool = True):
    comp = measured_compression(sparsity)
    rows = []
    for arch, shape in cells:
        path = os.path.join(dryrun_dir, f"{arch}__{shape}__16x16.json")
        if not os.path.exists(path):
            continue
        rec = json.load(open(path))
        total = rec["hbm_bytes_per_device"]
        wbytes = matmul_weight_bytes_per_device(arch)
        dense_t = total / HBM_BW
        sparse_total = total - wbytes + wbytes / comp
        sparse_t = sparse_total / HBM_BW
        rows.append({
            "arch": arch, "shape": shape,
            "total_bytes": total, "weight_bytes": wbytes,
            "weight_share": wbytes / total,
            "compression": comp,
            "step_dense_s": dense_t, "step_sparse_s": sparse_t,
            "speedup": dense_t / sparse_t,
        })
        if verbose:
            r = rows[-1]
            print(f"  {arch:16s} {shape:11s} weights {r['weight_share']:.0%}"
                  f" of {total/1e9:.1f}GB -> step {dense_t*1e3:.2f}ms"
                  f" => {sparse_t*1e3:.2f}ms ({r['speedup']:.2f}x)")
    return rows, {"bitmap_compression": comp}


def serve_trace_bench(arch: str = "olmo-1b", slots: int = 4,
                      n_requests: int = 16, rate: float = 0.5,
                      sparsity: float = 0.75, seed: int = 0,
                      smoke: bool = True, max_len: int = 64,
                      verbose: bool = True) -> dict:
    """Drive the continuous-batching engine with a seeded Poisson trace.

    Unlike the analytic rows above this *executes* the serving system:
    requests arrive mid-flight, freed slots are reused without a drain
    barrier, and every decode step streams the LM head through the
    bitmap-compressed ``kernels/ops`` path.  Reports measured tok/s and
    p50/p99 request latency — the serving-side analogue of the paper's
    traffic-cut headline.
    """
    from repro.launch.serve import serve_trace

    rep = serve_trace(arch, smoke=smoke, slots=slots, requests=n_requests,
                      rate=rate, max_len=max_len, sparsity=sparsity,
                      seed=seed, verbose=False)
    if verbose:
        lat = rep["latency_s"]
        print(f"  {arch:16s} slots={slots} requests={n_requests} "
              f"rate={rate}/step sparsity={sparsity:.0%}")
        print(f"    {rep['tok_per_s']:8.1f} tok/s | latency "
              f"p50 {lat['p50'] * 1e3:8.1f}ms  p99 {lat['p99'] * 1e3:8.1f}ms"
              f" | occupancy {rep['slot_occupancy']:.0%} | head "
              f"compression {rep['head_compression']:.2f}x")
    return rep


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", action="store_true",
                    help="run the live continuous-batching engine bench")
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--sparsity", type=float, default=0.75)
    args = ap.parse_args()
    if args.trace:
        serve_trace_bench(args.arch, slots=args.slots,
                          n_requests=args.requests, rate=args.rate,
                          sparsity=args.sparsity)
        return
    print(f"bitmap compression at 75% sparsity (measured, with overhead):"
          f" {measured_compression():.2f}x")
    run()


if __name__ == "__main__":
    main()
