"""Paper Fig. 7: random matrix multiplication across sparsity combinations.

1024×1024 GEMMs pruned to each (input, weight) sparsity pair; reports the
PE-utilisation / speed-up surface.  The paper's claim: >50 % utilisation
with substantial acceleration across the typical 50–70 % inference range.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.accelerator import AcceleratorConfig, run_gemm
from repro.core.bitmap import random_sparse

GRID = (0.3, 0.5, 0.6, 0.7, 0.8, 0.9)


def run(size: int = 1024, grid=GRID, max_row_tiles: int = 4, seed: int = 0,
        verbose: bool = True):
    rng = np.random.default_rng(seed)
    rows = []
    for sw in grid:
        for si in grid:
            x = random_sparse((size, size), si, rng)
            w = random_sparse((size, size), sw, rng)
            rep = run_gemm(x, w, AcceleratorConfig(),
                           max_row_tiles=max_row_tiles, seed=seed)
            rows.append({
                "input_sparsity": si, "weight_sparsity": sw,
                "utilization": rep.utilization,
                "speedup": rep.speedup_vs_dense,
                "mapm": rep.mapm,
            })
            if verbose:
                r = rows[-1]
                print(f"  si={si:.1f} sw={sw:.1f} util={r['utilization']:.2f}"
                      f" speedup={r['speedup']:.2f}x mapm={r['mapm']:.3f}",
                      flush=True)
    mid = [r for r in rows
           if 0.5 <= r["input_sparsity"] <= 0.7
           and 0.5 <= r["weight_sparsity"] <= 0.7]
    summary = {
        "mid_range_min_utilization": min(r["utilization"] for r in mid),
        "paper_claim_min_utilization": 0.50,
        "mid_range_avg_speedup": float(np.mean([r["speedup"] for r in mid])),
    }
    return rows, summary


def main():
    t0 = time.time()
    rows, s = run()
    print("\n== Fig. 7 sparsity sweep summary ==")
    for k, v in s.items():
        print(f"  {k:30s} {v:.4f}")
    print(f"({time.time() - t0:.1f}s)")
    return s


if __name__ == "__main__":
    main()
