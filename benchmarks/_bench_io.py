"""Shared BENCH_serve.json I/O for the serving benchmarks.

Every bench under ``benchmarks/`` merges its own section into one
shared JSON file (``--out BENCH_serve.json``) so downstream tooling
(`roofline.py`, EXPERIMENTS.md tables) reads a single artifact.  The
load → merge-preserving-others → write dance was copy-pasted five
times; this module is the one implementation, with two upgrades:

* **atomic write** — the merged file lands via ``tempfile`` +
  ``os.replace`` in the target directory, so a crashed or interrupted
  bench can never leave a half-written ``BENCH_serve.json`` behind;
* **timed sections** — ``bench_timer`` wraps a bench run and records
  its wall time into a ``repro.serve.telemetry.MetricsRegistry``
  histogram (``bench.<section>.wall_s``), and ``merge_section``
  stamps ``bench_wall_s`` into the section so the bench file carries
  how long each section took to produce.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import time
from typing import Dict, Iterator, Optional

from repro.serve.telemetry import MetricsRegistry

# the module-level registry every bench's timer records into; one
# process typically runs one bench, but a sweep driver importing
# several benches sees them all side by side in one snapshot
REGISTRY = MetricsRegistry()


def load_bench(path: str) -> Dict:
    """The bench file's current contents ({} when absent)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def write_atomic(path: str, data: Dict) -> None:
    """Write ``data`` as indented JSON via a same-directory tempfile +
    ``os.replace``: readers see the old file or the new file, never a
    torn one."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".bench_", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def merge_section(path: str, section: str, result: Dict,
                  wall_s: Optional[float] = None,
                  verbose: bool = True) -> Dict:
    """Merge ``result`` under ``data[section]``, preserving every other
    section, and write atomically.  ``wall_s`` (e.g. from
    ``bench_timer``) is stamped into the section as ``bench_wall_s``.
    Returns the full merged document."""
    data = load_bench(path)
    if wall_s is not None:
        result = {**result, "bench_wall_s": wall_s}
    data[section] = result
    write_atomic(path, data)
    if verbose:
        print(f"merged {section} section into {path}")
    return data


@contextlib.contextmanager
def bench_timer(section: str,
                registry: Optional[MetricsRegistry] = None) -> Iterator:
    """Time a bench run into ``bench.<section>.wall_s`` on the shared
    registry.  Yields an object whose ``.wall_s`` holds the elapsed
    seconds after the block exits — pass it to ``merge_section``."""
    reg = REGISTRY if registry is None else registry
    name = f"bench.{section}.wall_s"
    hist = (reg.get(name) if name in reg.names
            else reg.histogram(name,
                               help=f"wall time of the {section} bench"))

    class _Timing:
        wall_s: Optional[float] = None

    timing = _Timing()
    t0 = time.perf_counter()
    try:
        yield timing
    finally:
        timing.wall_s = time.perf_counter() - t0
        hist.observe(timing.wall_s)
