"""Shared-prefix COW page reuse + recompute-on-preempt: the data-reuse
proof sweep.

Drives the paged continuous-batching engine over a *shared-prefix*
Poisson trace — a handful of "system prompts" each reused by many
requests with unique tails, the serving analogue of the paper's SIDR
coordination (data fetched once, reused by everyone).  Four cells on
the identical trace, in two pool regimes:

* **roomy pool** (strict worst case, no admission queueing): reuse off
  vs on — repeated prefixes are adopted copy-on-write from resident
  pages and skip prefill, so TTFT p50 on a cache *hit* falls below the
  cold-*miss* p50 (the engine's hit/miss TTFT split proves it);
* **tight pool** (half the worst case): preempt+reuse off vs on at the
  *same* pool size — relaxed live-page commitment with recompute-on-
  preempt reclamation raises slot occupancy, and tokens stay identical
  to the baseline in every cell (the acceptance matrix).

``--out BENCH_serve.json`` merges a ``prefix_reuse`` section into the
existing bench file without clobbering the paging/prefill/arch sections
(scripts/ci.sh runs a smoke cell every CI pass).
"""
from __future__ import annotations

import argparse

try:                                    # script: benchmarks/ on sys.path
    from _bench_io import bench_timer, merge_section
except ImportError:                     # package: imported from repo root
    from benchmarks._bench_io import bench_timer, merge_section

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.serve import ServeEngine


def shared_prefix_trace(n_requests: int, rate: float, seed: int,
                        vocab_size: int, n_prefixes: int = 2,
                        prefix_len: int = 16, tail_len: int = 2,
                        max_new=(3, 8)):
    """Poisson arrivals where each request picks one of ``n_prefixes``
    shared system prompts and appends a unique tail — after each
    prefix's first (cold) request, every later one can hit the cache."""
    assert rate > 0
    r = np.random.default_rng(seed)
    prefixes = [[int(x) for x in r.integers(0, vocab_size, prefix_len)]
                for _ in range(n_prefixes)]
    t, out = 0.0, []
    for i in range(n_requests):
        t += float(r.exponential(1.0 / rate))
        pre = prefixes[i % n_prefixes]
        tail = [int(x) for x in r.integers(0, vocab_size, tail_len)]
        out.append({"prompt": pre + tail,
                    "max_new_tokens": int(r.integers(max_new[0],
                                                     max_new[1],
                                                     endpoint=True)),
                    "arrival": t})
    return out


def _run(cfg, trace, *, slots, max_len, sparsity, seed, page_len,
         pool_tokens, prefill_chunk, prefix_reuse, preempt):
    eng = ServeEngine(cfg, num_slots=slots, max_len=max_len,
                      sparsity=sparsity, seed=seed, head_sparsity=0.0,
                      paged=True, page_len=page_len,
                      page_pool_tokens=pool_tokens,
                      prefill_chunk=prefill_chunk,
                      prefix_reuse=prefix_reuse, preempt=preempt)
    reqs = []
    with eng.mesh:
        for spec in trace:
            reqs.append(eng.submit(**spec))
        rep = eng.run()
    return rep, [r.tokens for r in reqs]


def sweep(arch: str = "olmo-1b", smoke: bool = True, slots: int = 4,
          requests: int = 10, rate: float = 0.4, max_len: int = 64,
          sparsity: float = 0.5, page_len: int = 8,
          pool_tokens: int | None = None, prefill_chunk: int = 8,
          prefix_len: int = 16, seed: int = 0, repeats: int = 3,
          verbose: bool = True) -> dict:
    """Two paired comparisons on one identical shared-prefix trace,
    tokens identical across every cell (reuse and recompute are exact):

    * **roomy pool** (worst case, no queueing confound): reuse on vs
      off — the hit-vs-miss TTFT split isolates the skipped prefill;
    * **tight pool** (``pool_tokens``, default half the strict worst
      case): preempt+reuse on vs off — equal pool size, so the
      occupancy delta isolates relaxed live-page commitment.

    Each cell keeps the best-TTFT run of ``repeats``."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    trace = shared_prefix_trace(requests, rate, seed, cfg.vocab_size,
                                prefix_len=prefix_len,
                                max_new=(max(3, max_len // 8),
                                         max(3, max_len // 4)))
    worst = max(len(t["prompt"]) + t["max_new_tokens"] - 1
                for t in trace)
    if pool_tokens is None:
        pool_tokens = slots * worst // 2

    def best(pool, prefix_reuse, preempt):
        runs = [_run(cfg, trace, slots=slots, max_len=max_len,
                     sparsity=sparsity, seed=seed, page_len=page_len,
                     pool_tokens=pool, prefill_chunk=prefill_chunk,
                     prefix_reuse=prefix_reuse, preempt=preempt)
                for _ in range(repeats)]
        toks = runs[0][1]
        assert all(t == toks for _, t in runs), "nondeterministic run"
        return min((r for r, _ in runs),
                   key=lambda r: r["first_token_s"]["p50"]), toks

    base, base_toks = best(None, False, False)
    reuse, reuse_toks = best(None, True, False)
    tight, tight_toks = best(pool_tokens, False, False)
    both, both_toks = best(pool_tokens, True, True)
    assert reuse_toks == base_toks, "prefix reuse changed tokens"
    assert tight_toks == base_toks, "tight pool changed tokens"
    assert both_toks == base_toks, "preemption changed tokens"

    pr, pb = reuse["prefix_reuse"], both["prefix_reuse"]
    result = {
        "arch": arch, "slots": slots, "requests": requests,
        "page_len": page_len, "pool_tokens": pool_tokens,
        "prefix_len": prefix_len, "prefill_chunk": prefill_chunk,
        "tokens_identical": True,
        "baseline": {
            "ttft_p50_s": base["first_token_s"]["p50"],
            "tok_per_s": base["tok_per_s"],
            "slot_occupancy": base["slot_occupancy"],
        },
        "reuse": {
            "ttft_hit_p50_s": pr["ttft_hit_s"]["p50"],
            "ttft_miss_p50_s": pr["ttft_miss_s"]["p50"],
            "ttft_hit_speedup": (pr["ttft_miss_s"]["p50"]
                                 / pr["ttft_hit_s"]["p50"]),
            "hits": pr["hits"], "misses": pr["misses"],
            "hit_tokens": pr["hit_tokens"], "forks": pr["forks"],
            "evictions": pr["evictions"],
            "tok_per_s": reuse["tok_per_s"],
        },
        "tight_baseline": {
            "slot_occupancy": tight["slot_occupancy"],
            "tok_per_s": tight["tok_per_s"],
        },
        "reuse_preempt": {
            "slot_occupancy": both["slot_occupancy"],
            "occupancy_gain": (both["slot_occupancy"]
                               / tight["slot_occupancy"]
                               if tight["slot_occupancy"] else 1.0),
            "preemptions": pb["preempt"]["count"],
            "recomputed_tokens": pb["preempt"]["recomputed_tokens"],
            "evictions": pb["evictions"],
            "tok_per_s": both["tok_per_s"],
        },
    }
    if verbose:
        r, p = result["reuse"], result["reuse_preempt"]
        print(f"  {arch:10s} slots={slots} | TTFT p50 hit "
              f"{r['ttft_hit_p50_s'] * 1e3:6.1f}ms vs miss "
              f"{r['ttft_miss_p50_s'] * 1e3:6.1f}ms "
              f"({r['ttft_hit_speedup']:.2f}x) | {r['hits']} hits / "
              f"{r['misses']} misses, {r['hit_tokens']} tokens adopted, "
              f"{r['forks']} forks, {r['evictions']} evictions")
        print(f"  tight pool {pool_tokens}tok: occupancy "
              f"{result['tight_baseline']['slot_occupancy']:.0%} -> "
              f"{p['slot_occupancy']:.0%} with reuse+preempt "
              f"({p['occupancy_gain']:.2f}x at equal pool), "
              f"{p['preemptions']} preempts / {p['recomputed_tokens']} "
              f"tokens recomputed | tokens identical across all cells")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--rate", type=float, default=0.4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--page-len", type=int, default=8)
    ap.add_argument("--pool-tokens", type=int, default=None,
                    help="per-pool page budget in tokens (default: half "
                         "the strict worst case, so preemption engages)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt length in tokens")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="merge a 'prefix_reuse' section into this JSON "
                         "file (e.g. BENCH_serve.json)")
    args = ap.parse_args()
    with bench_timer("prefix_reuse") as timing:
        result = sweep(args.arch, smoke=args.smoke, slots=args.slots,
                       requests=args.requests, rate=args.rate,
                       max_len=args.max_len, sparsity=args.sparsity,
                       page_len=args.page_len,
                       pool_tokens=args.pool_tokens,
                       prefill_chunk=args.prefill_chunk,
                       prefix_len=args.prefix_len, seed=args.seed,
                       repeats=args.repeats)
    if args.out:
        merge_section(args.out, "prefix_reuse", result,
                      wall_s=timing.wall_s)


if __name__ == "__main__":
    main()
