"""Whole-stack bitmap weight streaming at serve time: the proof sweep.

Drives the continuous-batching engine over a (arch × sparsity × slots)
grid, once with the whole decode stack packed (``pack_model`` + bitmap
LM head) and once with dense dispatch, on the same seeded Poisson trace
— so each cell reports:

* measured tok/s, packed vs dense (packing is lossless, so the tokens
  are identical and any delta is pure dispatch overhead);
* the engine's modeled per-step weight-HBM bytes across the stack
  (sparse vs dense) and the resulting reduction — the serve-time
  analogue of the paper's 86 % SRAM-access cut.  MoE rows count expert
  stacks once per *activated* expert per step (min(E, slots × top_k) —
  the accounting rule in DESIGN_PACKED.md), and since PR 5 the MoE
  expert stacks and SSM mixer projections themselves stream compressed,
  so the granite-moe / jamba rows measure the full-stack cut;
* how many tensors packed vs fell back to dense (with reasons in the
  engine report).

``--archs`` sweeps several architectures in one run (CI covers an
attn/MLP arch, an MoE arch and the jamba hybrid); ``--out
BENCH_serve.json`` merges ``rows`` + per-arch ``headlines`` into the
bench file, preserving the other tools' sections.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, get_smoke_config
from repro.serve import ServeEngine, poisson_trace

try:                                    # script: benchmarks/ on sys.path
    from _bench_io import bench_timer, load_bench, write_atomic
except ImportError:                     # package: imported from repo root
    from benchmarks._bench_io import bench_timer, load_bench, write_atomic


def _run_engine(cfg, *, slots: int, sparsity: float, requests: int,
                rate: float, max_len: int, seed: int,
                stream_weights: bool, model_parallel: int = 1) -> dict:
    # head_sparsity=0.0 streams the *exact* head bitmap-packed, so the
    # packed and dense engines decode identical tokens at any sparsity
    # and the tok/s delta is pure dispatch overhead (the serving regime
    # additionally prunes the head — report()["head_compression"]).
    eng = ServeEngine(cfg, num_slots=slots, max_len=max_len,
                      sparsity=sparsity, seed=seed,
                      model_parallel=model_parallel,
                      stream_weights=stream_weights,
                      bitmap_head=stream_weights,
                      head_sparsity=0.0 if stream_weights else None)
    hi = max(1, min(16, max_len - 4))
    trace = poisson_trace(requests, rate=rate, seed=seed,
                          vocab_size=cfg.vocab_size,
                          prompt_len=(1, 4), max_new=(max(1, hi // 2), hi))
    with eng.mesh:
        for spec in trace:
            eng.submit(**spec)
        return eng.run()


def sweep(arch: str = "olmo-1b", smoke: bool = True,
          sparsities=(0.0, 0.5, 0.75), slots_list=(2, 4),
          requests: int = 12, rate: float = 0.7, max_len: int = 48,
          seed: int = 0, repeats: int = 3, verbose: bool = True) -> dict:
    """(sparsity × slots) grid: packed-streaming engine vs dense-dispatch
    baseline on identical traces.

    Each cell runs ``repeats`` times per engine and keeps the best tok/s
    — smoke runs finish in well under a second, so a single run's wall
    clock is scheduler-noise-dominated."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    rows = []

    def best_of(**kw):
        reps = [_run_engine(cfg, **kw) for _ in range(repeats)]
        return max(reps, key=lambda r: r["tok_per_s"])

    for sparsity in sparsities:
        for slots in slots_list:
            kw = dict(slots=slots, sparsity=sparsity, requests=requests,
                      rate=rate, max_len=max_len, seed=seed)
            packed = best_of(stream_weights=True, **kw)
            dense = best_of(stream_weights=False, **kw)
            ws = packed["weight_stream"]
            row = {
                "arch": arch, "sparsity": sparsity, "slots": slots,
                "tok_per_s": packed["tok_per_s"],
                "tok_per_s_dense": dense["tok_per_s"],
                "tok_per_s_ratio": (packed["tok_per_s"]
                                    / dense["tok_per_s"]),
                "weight_bytes_per_step": ws["sparse_bytes_per_step"],
                "weight_bytes_per_step_dense": ws["dense_bytes_per_step"],
                "hbm_reduction": ws["reduction"],
                "packed_tensors": ws["packed_tensors"],
                "fallback_tensors": ws["fallback_tensors"],
                "head_compression": packed["head_compression"],
            }
            rows.append(row)
            if verbose:
                print(f"  {arch:10s} sparsity={sparsity:.2f} "
                      f"slots={slots} | {row['tok_per_s']:8.1f} tok/s "
                      f"(dense {row['tok_per_s_dense']:8.1f}, "
                      f"{row['tok_per_s_ratio']:.2f}x) | weight HBM "
                      f"{row['weight_bytes_per_step']/1e6:6.2f}MB vs "
                      f"{row['weight_bytes_per_step_dense']/1e6:6.2f}MB "
                      f"({row['hbm_reduction']:.2f}x)")
    target = [r for r in rows if r["sparsity"] >= 0.75]
    headline = {
        "arch": arch,
        "hbm_reduction_at_75": (min(r["hbm_reduction"] for r in target)
                                if target else None),
        # the acceptance regime is the 75 %-sparsity serving cells
        "tok_per_s_ratio_at_75": (min(r["tok_per_s_ratio"] for r in target)
                                  if target else None),
        "tok_per_s_ratio_worst": min(r["tok_per_s_ratio"] for r in rows),
        "fallback_tensors": rows[-1]["fallback_tensors"],
    }
    if verbose and target:
        print(f"  headline: >= {headline['hbm_reduction_at_75']:.2f}x "
              f"modeled per-step weight-HBM cut at 75% sparsity; "
              f"packed/dense tok/s ratio there "
              f"{headline['tok_per_s_ratio_at_75']:.2f}")
    return {"rows": rows, "headline": headline}


def mp_sweep(arch: str, mp: int, smoke: bool = True,
             sparsity: float = 0.75, slots: int = 8, requests: int = 8,
             rate: float = 0.7, max_len: int = 48, seed: int = 0,
             repeats: int = 2, verbose: bool = True) -> dict:
    """One sharded-serving cell: the packed engine at ``model_parallel=
    mp`` on whatever device topology the process was launched with
    (CI forces 8 fake host devices via XLA_FLAGS).  Reports tok/s plus
    the per-device vs total weight-HBM bytes — the 1/mp storage cut the
    sharded layout exists for."""
    import jax

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    kw = dict(slots=slots, sparsity=sparsity, requests=requests,
              rate=rate, max_len=max_len, seed=seed,
              stream_weights=True, model_parallel=mp)
    rep = max((_run_engine(cfg, **kw) for _ in range(repeats)),
              key=lambda r: r["tok_per_s"])
    ws = rep["weight_stream"]
    row = {
        "arch": arch, "model_parallel": mp,
        "devices": jax.device_count(),
        "shards": ws["shards"],
        "tok_per_s": rep["tok_per_s"],
        "weight_bytes_per_step": ws["sparse_bytes_per_step"],
        "device_weight_bytes_per_step": ws["device_sparse_bytes_per_step"],
        "device_fraction": (ws["device_sparse_bytes_per_step"]
                            / max(ws["sparse_bytes_per_step"], 1)),
        "shard_fallbacks": len(ws["shard_fallbacks"]),
    }
    if verbose:
        print(f"  {arch:10s} mp={mp} ({row['devices']} devices, "
              f"{row['shards']} shards) | {row['tok_per_s']:8.1f} tok/s "
              f"| per-device weight HBM "
              f"{row['device_weight_bytes_per_step']/1e6:6.2f}MB of "
              f"{row['weight_bytes_per_step']/1e6:6.2f}MB/step "
              f"({row['device_fraction']:.2f}x)")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", "--arch", nargs="+", default=["olmo-1b"],
                    help="architectures to sweep (one set of rows each)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sparsities", type=float, nargs="+",
                    default=[0.0, 0.5, 0.75])
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=0.7)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="run ONLY the sharded-serving cell at this mp "
                         "(launch with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=8 for a real mesh); "
                         "merges under the separate 'model_parallel' "
                         "key, leaving the single-device rows intact")
    ap.add_argument("--out", default=None,
                    help="merge rows + per-arch headlines into this JSON "
                         "file (e.g. BENCH_serve.json)")
    args = ap.parse_args()
    if args.model_parallel:
        import jax
        with bench_timer("bitmap_streaming_mp") as timing:
            mp_rows = [mp_sweep(arch, args.model_parallel,
                                smoke=args.smoke,
                                sparsity=max(args.sparsities),
                                requests=args.requests, rate=args.rate,
                                max_len=args.max_len, seed=args.seed,
                                repeats=args.repeats)
                       for arch in args.archs]
        if args.out:
            data = load_bench(args.out)
            data["model_parallel"] = {"devices": jax.device_count(),
                                      "rows": mp_rows,
                                      "wall_s": timing.wall_s}
            write_atomic(args.out, data)
            print(f"merged {len(mp_rows)} model_parallel rows "
                  f"into {args.out}")
        return
    rows, headlines = [], {}
    with bench_timer("bitmap_streaming") as timing:
        for arch in args.archs:
            result = sweep(arch, smoke=args.smoke,
                           sparsities=tuple(args.sparsities),
                           slots_list=tuple(args.slots),
                           requests=args.requests, rate=args.rate,
                           max_len=args.max_len, seed=args.seed,
                           repeats=args.repeats)
            rows.extend(result["rows"])
            headlines[arch] = result["headline"]
    if args.out:
        data = load_bench(args.out)
        data.pop("headline", None)      # superseded by per-arch headlines
        data["rows"] = rows
        data["headlines"] = headlines
        data["bitmap_streaming_wall_s"] = timing.wall_s
        write_atomic(args.out, data)
        print(f"merged {len(rows)} rows + headlines into {args.out}")


if __name__ == "__main__":
    main()
