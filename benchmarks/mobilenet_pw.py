"""Paper §III-A / Fig. 6 / Table I: MobileNetV2 PW layers on the accelerator.

Per-PW-layer PE utilisation + speed-up (Fig. 6), average MAPM and the SRAM
reduction vs SparTen (the 0.29 B/MAC and 86 % headlines), energy efficiency
(Table I).  Weights: 75 % global-L1 pruned (paper); activations: synthetic
post-ReLU6 sparsity for project layers, dense for expand layers (linear
bottleneck) — deviation recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.accelerator import AcceleratorConfig, run_gemm
from repro.core.bitmap import prune_global_l1, random_sparse
from repro.core.energy import energy_from_stats, tops_per_watt
from repro.core.mapm import SPARTEN_PAPER_MAPM
from repro.core.mobilenet import pw_layers


def run(weight_sparsity: float = 0.75, act_sparsity: float = 0.45,
        max_row_tiles: int = 8, seed: int = 0, verbose: bool = True):
    rng = np.random.default_rng(seed)
    rows = []
    for layer in pw_layers():
        w = prune_global_l1(
            rng.standard_normal((layer.n, layer.k)).astype(np.float32),
            weight_sparsity)
        si = act_sparsity if layer.input_relu else 0.0
        x = random_sparse((layer.m, layer.k), si, rng)
        rep = run_gemm(x, w, AcceleratorConfig(),
                       max_row_tiles=max_row_tiles, seed=seed)
        e = energy_from_stats(rep.stats)
        rows.append({
            "layer": layer.name, "m": layer.m, "k": layer.k, "n": layer.n,
            "input_sparsity": si,
            "mapm": rep.mapm,
            "utilization": rep.utilization,
            "speedup": rep.speedup_vs_dense,
            "macs": rep.stats.macs,
            "sram_bytes": rep.stats.sram_bytes,
            "tops_per_watt": tops_per_watt(rep.stats.macs, e.total_j),
        })
        if verbose:
            r = rows[-1]
            print(f"  {r['layer']:18s} ({r['m']:5d}x{r['k']:4d}x{r['n']:4d})"
                  f" util={r['utilization']:.2f} speedup={r['speedup']:.2f}x"
                  f" mapm={r['mapm']:.3f}", flush=True)

    total_macs = sum(r["macs"] for r in rows)
    w_util = sum(r["utilization"] * r["macs"] for r in rows) / total_macs
    w_speed = sum(r["speedup"] * r["macs"] for r in rows) / total_macs
    avg_mapm = sum(r["sram_bytes"] for r in rows) / total_macs
    summary = {
        "avg_mapm_byte_per_mac": avg_mapm,
        "paper_mapm": 0.29,
        "sram_reduction_vs_sparten": 1 - avg_mapm / SPARTEN_PAPER_MAPM,
        "paper_sram_reduction": 0.86,
        "overall_utilization": w_util,
        "paper_utilization": 0.66,
        "overall_speedup": w_speed,
        "paper_speedup": 2.1,
        "tops_per_watt": (sum(r["tops_per_watt"] * r["macs"] for r in rows)
                          / total_macs),
        "paper_tops_per_watt": 1.198,
    }
    return rows, summary


def main():
    t0 = time.time()
    rows, s = run()
    print("\n== MobileNetV2 PW summary (paper §III-A) ==")
    for k, v in s.items():
        print(f"  {k:30s} {v:.4f}" if isinstance(v, float) else f"  {k}: {v}")
    print(f"({time.time() - t0:.1f}s)")
    return s


if __name__ == "__main__":
    main()
